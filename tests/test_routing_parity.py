"""Dispatch ↔ simulator parity suite (ISSUE 2 acceptance gate).

PR 1's ViBE-R solver computes speed-proportional per-copy traffic shares;
this suite proves the *model layer's* replica selection realizes them: the
per-rank traffic produced by ``_select_slots`` on a Zipf-skewed workload
must match the per-rank loads the simulator (and the latency objective)
scores, within 5% relative error — and the legacy uniform ``% n_copies``
hash must *violate* that bound on the same fixture, so a regression back
to share-oblivious dispatch trips loudly.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import PerfModel, vibe_r_placement
from repro.models import build_copy_cdf, build_slots_of
from repro.models import moe as MOE
from repro.serving import EPSimulator, SimConfig, realized_rank_loads

#: acceptance bound (ISSUE 2): ≤ 5% relative error on every rank's load
TOL = 0.05


def affine_perf(slopes, base=5e-4):
    """Deterministic heterogeneous rank models f_g(n) = base + slope_g·n.

    Synthetic (not cluster-calibrated) so the fixture is stable under
    profiling refactors; the 1:8 slope spread produces strongly skewed
    copy shares — the regime where uniform hashing is wrong.
    """
    return [PerfModel(knots=np.array([0.0, 1e6]),
                      lat=np.array([base, base + s * 1e6]), device_id=g)
            for g, s in enumerate(slopes)]


def skewed_fixture(seed=7, E=16, L=2, slots_per_rank=5, tokens=100_000.0,
                   alpha=1.4):
    """Zipf-skewed loads on a 1:8 speed-spread 4-rank cluster."""
    rng = np.random.default_rng(seed)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    z = 1.0 / np.arange(1, E + 1) ** alpha
    prof = np.stack([rng.permutation(z / z.sum()) for _ in range(L)])
    w = prof * tokens
    rp = vibe_r_placement(w, perf, slots_per_rank=slots_per_rank)
    return rng, perf, prof, rp


def draw_assignments(rng, prof_layer, t, top_k=2):
    """(t, K) logical routing draws from a per-layer expert profile."""
    return rng.choice(prof_layer.size, size=(t, top_k),
                      p=prof_layer).astype(np.int32)


def dispatch_rank_loads(rp, idx, layer, weighted=True):
    """Per-rank assignment counts exactly as model dispatch realizes them:
    logical ids → physical slots via ``_select_slots`` (inverse-CDF over
    the placement's share table, or the legacy uniform hash), slots →
    ranks by the rank-major slot layout."""
    slots_of, n_copies = build_slots_of(rp.perm, rp.n_experts, rp.n_slots)
    cdf = jnp.asarray(rp.copy_cdf()[layer]) if weighted else None
    slots = np.asarray(MOE._select_slots(
        jnp.asarray(idx), jnp.asarray(slots_of[layer]),
        jnp.asarray(n_copies[layer]), cdf))
    return np.bincount(slots.ravel() // rp.slots_per_rank,
                       minlength=rp.n_ranks).astype(np.float64)


def per_layer_loads(idx, E):
    return np.bincount(idx.ravel(), minlength=E).astype(np.float64)


# ---------------------------------------------------------------------------
# the parity bound, both directions
# ---------------------------------------------------------------------------

def test_weighted_dispatch_matches_simulator_loads():
    """Acceptance criterion: realized per-rank loads from model-layer
    dispatch match simulator-predicted loads within 5% relative error on a
    Zipf-skewed, heterogeneous-speed fixture."""
    rng, _, prof, rp = skewed_fixture()
    for layer in range(prof.shape[0]):
        idx = draw_assignments(rng, prof[layer], t=50_000)
        loads = per_layer_loads(idx, rp.n_experts)
        predicted = rp.rank_loads(                    # what the sim scores
            np.tile(loads, (rp.n_layers, 1)))[layer]
        dispatched = dispatch_rank_loads(rp, idx, layer, weighted=True)
        rel = np.abs(dispatched - predicted) / predicted
        assert rel.max() <= TOL, (layer, rel)


def test_uniform_hash_violates_parity_bound():
    """Regression tripwire: the pre-change uniform ``% n_copies`` hash must
    FAIL the 5% bound on the skewed-shares fixture. If this ever passes
    with uniform selection, the fixture no longer discriminates and the
    parity test above proves nothing."""
    rng, _, prof, rp = skewed_fixture()
    worst = 0.0
    for layer in range(prof.shape[0]):
        idx = draw_assignments(rng, prof[layer], t=50_000)
        loads = per_layer_loads(idx, rp.n_experts)
        predicted = rp.rank_loads(np.tile(loads, (rp.n_layers, 1)))[layer]
        dispatched = dispatch_rank_loads(rp, idx, layer, weighted=False)
        worst = max(worst, float(
            (np.abs(dispatched - predicted) / predicted).max()))
    assert worst > TOL, f"uniform hash unexpectedly within bound ({worst})"


def test_dispatch_matches_token_granular_realized_loads():
    """The simulator's realized_loads mode and the actual hash dispatch
    describe the same integer token split (± hash noise, well under the
    parity bound)."""
    rng, _, prof, rp = skewed_fixture()
    idx = draw_assignments(rng, prof[0], t=50_000)
    loads = per_layer_loads(idx, rp.n_experts)
    realized = realized_rank_loads(rp, np.tile(loads, (rp.n_layers, 1)))[0]
    dispatched = dispatch_rank_loads(rp, idx, 0, weighted=True)
    rel = np.abs(dispatched - realized) / realized
    assert rel.max() <= TOL


# ---------------------------------------------------------------------------
# responsive (stolen) shares keep parity — ISSUE 7 acceptance gate
# ---------------------------------------------------------------------------

def stolen_fixture(**kw):
    """The skewed fixture's placement after genuine work-stealing steps:
    a TokenRescheduler fed a load mix shifted away from the profiled skew,
    so the responsive share table has visibly diverged from the plan."""
    from repro.core import StealConfig, TokenRescheduler

    rng, perf, prof, rp = skewed_fixture(**kw)
    rs = TokenRescheduler(StealConfig(headroom=0.0, max_shift=0.5,
                                      smoothing=1.0), perf)
    rs.reset(rp)
    for _ in range(3):
        rs.observe(np.roll(prof, 5, axis=1) * 100_000)
    assert rs.steals > 0, "fixture failed to trigger a steal"
    dp = rs.placement
    assert np.abs(dp.share - rp.share).max() > 1e-3
    return rng, prof, dp


def test_stolen_shares_dispatch_matches_simulator_loads():
    """The 5% dispatch↔simulator parity bound holds for *responsive*
    (steal-adjusted) share tables exactly as it does for the solver's plan
    — the rescheduler's reweighting stays inside what inverse-CDF replica
    selection can realize."""
    rng, prof, dp = stolen_fixture()
    for layer in range(prof.shape[0]):
        idx = draw_assignments(rng, prof[layer], t=50_000)
        loads = per_layer_loads(idx, dp.n_experts)
        predicted = dp.rank_loads(np.tile(loads, (dp.n_layers, 1)))[layer]
        dispatched = dispatch_rank_loads(dp, idx, layer, weighted=True)
        rel = np.abs(dispatched - predicted) / predicted
        assert rel.max() <= TOL, (layer, rel)


def test_stolen_shares_token_granular_parity_and_conservation():
    """Token-granular scoring of stolen shares: realized_rank_loads agrees
    with hash dispatch within the parity bound and conserves every token."""
    rng, prof, dp = stolen_fixture()
    idx = draw_assignments(rng, prof[0], t=50_000)
    loads = per_layer_loads(idx, dp.n_experts)
    tiled = np.tile(loads, (dp.n_layers, 1))
    realized = realized_rank_loads(dp, tiled)
    np.testing.assert_allclose(realized.sum(1), tiled.sum(1))
    dispatched = dispatch_rank_loads(dp, idx, 0, weighted=True)
    assert dispatched.sum() == idx.size           # every draw lands somewhere
    rel = np.abs(dispatched - realized[0]) / realized[0]
    assert rel.max() <= TOL


def test_stolen_shares_preserve_moe_semantics_and_drop_column():
    """Ragged dispatch through a *stolen* share table: outputs and logical
    tallies equal the singleton reference (copies hold identical weights),
    i.e. stealing never drops a token — the drop column stays structurally
    zero."""
    import jax

    _, _, dp = stolen_fixture(E=8, slots_per_rank=3)
    E, D, F, K = 8, 32, 64, 2
    p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, D)) \
        .astype(jnp.bfloat16)
    y_ref, tally_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E,
                                        rules=None)
    perm = dp.perm[0]
    p_rep = dict(p)
    for k in ("w1", "w2", "w3"):
        p_rep[k] = p[k][perm]
    slots_of, n_copies = build_slots_of(dp.perm, E, dp.n_slots)
    cdf = dp.copy_cdf()
    y, tally, _ = MOE.moe_layer(p_rep, x, top_k=K, n_experts=E, rules=None,
                                slots_of=jnp.asarray(slots_of[0]),
                                n_copies=jnp.asarray(n_copies[0]),
                                copy_cdf=jnp.asarray(cdf[0]))
    err = float(jnp.abs(y_ref.astype(jnp.float32)
                        - y.astype(jnp.float32)).max())
    assert err < 1e-5, err
    np.testing.assert_allclose(np.asarray(tally_ref), np.asarray(tally))


# ---------------------------------------------------------------------------
# realized_rank_loads (simulator side of the seam)
# ---------------------------------------------------------------------------

def test_realized_loads_conserve_and_track_shares():
    rng, _, prof, rp = skewed_fixture()
    loads = np.round(prof * 100_000)
    realized = realized_rank_loads(rp, loads)
    # token conservation: apportionment loses/creates nothing
    np.testing.assert_allclose(realized.sum(1), loads.sum(1))
    # integer split (whole tokens) ...
    np.testing.assert_allclose(realized, np.round(realized))
    # ... that deviates from the fractional shares by < 1 token per slot
    frac = rp.rank_loads(loads)
    assert np.abs(realized - frac).max() < rp.slots_per_rank


def test_realized_loads_singleton_passthrough():
    from repro.core import eplb_placement
    rng = np.random.default_rng(0)
    w = np.round(rng.random((3, 16)) * 1000)
    pl = eplb_placement(w, 4)
    np.testing.assert_allclose(realized_rank_loads(pl, w), pl.rank_loads(w))


def test_simulator_realized_loads_mode():
    """SimConfig.realized_loads scores whole-token dispatched traffic: the
    recorded per-rank loads are integers and conserve the drawn loads."""
    from repro.configs import get
    from repro.core import make_cluster
    from repro.serving import WORKLOADS, sample_requests

    model = get("deepseek-v3-671b")
    cluster = make_cluster(8, "mi325x", d_model=model.d_model,
                           d_ff=model.moe_d_ff,
                           experts_per_rank=model.n_experts // 8)
    from repro.serving import routing_profile
    W = routing_profile(WORKLOADS["sonnet"], model._n_moe_layers(),
                        model.n_experts) * 16384 * model.top_k
    rp = vibe_r_placement(W, cluster.fit_models(), slots_per_rank=
                          model.n_experts // 8 + 1)
    sim = EPSimulator(model, cluster, WORKLOADS["sonnet"],
                      SimConfig(ep_degree=8, seed=1, realized_loads=True,
                                record_layer_stats=True,
                                max_prefill_tokens=8192),
                      placement=rp)
    sim.run(sample_requests(WORKLOADS["sonnet"], 3, qps=50.0, seed=2),
            phase="prefill")
    assert sim.layer_stats, "no layer stats recorded"
    for st in sim.layer_stats:
        np.testing.assert_allclose(st.rank_load, np.round(st.rank_load))


# ---------------------------------------------------------------------------
# share-table construction agrees across the core ↔ models seam
# ---------------------------------------------------------------------------

def test_copy_cdf_tables_agree_across_layers():
    """ReplicatedPlacement.copy_cdf (core) and build_copy_cdf (models)
    must produce the same table (both delegate to the canonical
    copy_enumeration, but the normalization/padding paths differ)."""
    _, _, _, rp = skewed_fixture()
    a = rp.copy_cdf()
    b = build_copy_cdf(rp.perm, rp.n_experts, rp.n_slots, share=rp.share)
    np.testing.assert_allclose(a, b, atol=1e-6)
    # r_max padding extends with 1.0, never changes real entries
    a_pad = rp.copy_cdf(r_max=a.shape[-1] + 2)
    np.testing.assert_allclose(a_pad[..., :a.shape[-1]], a, atol=1e-12)
    assert (a_pad[..., a.shape[-1]:] == 1.0).all()


def test_moe_layer_weighted_tables_preserve_semantics():
    """Weighted replica selection only redistributes load: outputs and
    logical tallies through a share-weighted ViBE-R slot table equal the
    singleton identity layout (copies hold identical weights)."""
    import jax

    _, _, _, rp = skewed_fixture(E=8, slots_per_rank=3)
    E, D, F, K = 8, 32, 64, 2
    p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, D)) \
        .astype(jnp.bfloat16)
    y_ref, tally_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E,
                                        rules=None)
    perm = rp.perm[0]
    p_rep = dict(p)
    for k in ("w1", "w2", "w3"):
        p_rep[k] = p[k][perm]
    slots_of, n_copies = build_slots_of(rp.perm, E, rp.n_slots)
    cdf = rp.copy_cdf()
    y, tally, _ = MOE.moe_layer(p_rep, x, top_k=K, n_experts=E, rules=None,
                                slots_of=jnp.asarray(slots_of[0]),
                                n_copies=jnp.asarray(n_copies[0]),
                                copy_cdf=jnp.asarray(cdf[0]))
    err = float(jnp.abs(y_ref.astype(jnp.float32)
                        - y.astype(jnp.float32)).max())
    assert err < 1e-5, err
    np.testing.assert_allclose(np.asarray(tally_ref), np.asarray(tally))


# ---------------------------------------------------------------------------
# engine integration: the share table rides the no-recompile path
# ---------------------------------------------------------------------------

class TestEngineShareTables:
    def _engine(self, weighted=True):
        from repro.configs import get_smoke
        from repro.core import (DriftConfig, ViBEConfig, ViBEController,
                                make_cluster)
        from repro.models import moe_perm_shape
        from repro.serving import Engine

        cfg = get_smoke("qwen3-moe-235b-a22b")
        n_moe, n_slots = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                               d_ff=cfg.moe_d_ff,
                               experts_per_rank=n_slots // 4)
        ctl = ViBEController(
            n_moe, n_slots, 4, cluster.fit_models(),
            ViBEConfig(policy="vibe_r",
                       drift=DriftConfig(window=8, interval=4, cooldown=4)))
        return Engine(cfg, controller=ctl, cluster=cluster, max_batch=2,
                      max_seq=48, weighted_routing=weighted, seed=0)

    def test_engine_applies_solver_share_table(self):
        eng = self._engine(weighted=True)
        cdf = np.asarray(eng.moe_tables[2]).reshape(eng.n_moe,
                                                    eng.cfg.n_experts, -1)
        want = eng.controller.placement.copy_cdf(r_max=cdf.shape[-1])
        np.testing.assert_allclose(cdf, want, atol=1e-6)

    def test_engine_uniform_routing_knob(self):
        """weighted_routing=False keeps the share-oblivious uniform CDF —
        the serve driver's --uniform-replica-routing A/B path."""
        eng = self._engine(weighted=False)
        cdf = np.asarray(eng.moe_tables[2]).reshape(eng.n_moe,
                                                    eng.cfg.n_experts, -1)
        nc = eng.controller.placement.n_copies()
        r = cdf.shape[-1]
        uniform = np.minimum(
            np.arange(1, r + 1)[None, None, :] / nc[..., None], 1.0)
        np.testing.assert_allclose(cdf, uniform, atol=1e-6)

    def test_virtual_clock_prices_dispatch_mode(self):
        """The engine clock charges the *realized* loads of the active
        routing mode: weighted engines price the solver's shares, uniform
        engines price a uniform split over the same slot table."""
        from repro.serving.simulator import rank_latency_matrix

        eng_w = self._engine(weighted=True)
        eng_u = self._engine(weighted=False)
        pl = eng_w.controller.placement
        # weighted: clock placement IS the controller placement
        assert eng_w._clock_placement() is pl
        # uniform: same slot table, flat shares
        cp = eng_u._clock_placement()
        np.testing.assert_array_equal(cp.slot_expert,
                                      eng_u.controller.placement.slot_expert)
        nc = cp.n_copies()
        np.testing.assert_allclose(
            cp.share,
            1.0 / np.take_along_axis(nc, cp.slot_expert, axis=1))
        # and _charge prices exactly those realized loads
        rng = np.random.default_rng(0)
        tall = np.concatenate(
            [np.round(rng.random((eng_w.n_moe, eng_w.cfg.n_experts)) * 500),
             np.zeros((eng_w.n_moe, 1))], axis=1)
        dt = eng_w._charge(tall, 64)
        want = float(rank_latency_matrix(
            eng_w.cluster,
            realized_rank_loads(pl, eng_w._controller_tallies(tall)))
            .max(1).sum())
        assert dt == want

    def test_share_table_shapes_stable_across_recalibration(self):
        """A new placement with different replication degrees must reuse the
        pinned copy-axis width — the no-recompile discipline."""
        eng = self._engine(weighted=True)
        shapes0 = tuple(t.shape for t in eng.moe_tables)
        rng = np.random.default_rng(3)
        E = eng.controller.E
        w = rng.dirichlet(np.full(E, 0.2), size=eng.n_moe) * 10_000
        rp = vibe_r_placement(w, eng.controller.perf_models,
                              slots_per_rank=eng.n_slots // 4)
        eng.controller.placement = rp
        eng._apply_perm(eng._controller_perm(), share=eng._controller_share())
        assert tuple(t.shape for t in eng.moe_tables) == shapes0
