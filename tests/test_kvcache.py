"""Property tests for the paged KV cache's memory-accounting invariants.

The allocator decides what may run; corruption here surfaces as
cross-request KV reuse, so the invariants are pinned hard: a block is
never double-assigned, never leaked across request lifecycles, and the
watermark floor is never breached by admission.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving import BlockAllocator, KVCacheConfig, PagedKVCache


class TestBlockAllocator:
    def test_alloc_free_roundtrip(self):
        a = BlockAllocator(8)
        blocks = a.alloc(5)
        assert len(blocks) == len(set(blocks)) == 5
        assert a.n_free == 3
        a.free(blocks)
        assert a.n_free == 8

    def test_exhaustion_raises(self):
        a = BlockAllocator(4)
        a.alloc(4)
        with pytest.raises(MemoryError):
            a.alloc(1)

    def test_double_free_raises(self):
        a = BlockAllocator(4)
        blocks = a.alloc(2)
        a.free(blocks)
        with pytest.raises(ValueError):
            a.free(blocks)

    def test_foreign_block_raises(self):
        a = BlockAllocator(4)
        a.alloc(1)
        with pytest.raises(ValueError):
            a.free([3])              # never handed out

    @settings(max_examples=30, deadline=None)
    @given(n_blocks=st.integers(1, 64), seed=st.integers(0, 2 ** 16))
    def test_never_double_assigned(self, n_blocks, seed):
        """Random alloc/free interleavings: live block sets stay disjoint
        and alloc+free partitions the pool exactly."""
        rng = np.random.default_rng(seed)
        a = BlockAllocator(n_blocks)
        live = {}                    # handle -> blocks
        for _ in range(50):
            if live and rng.random() < 0.4:
                h = list(live)[int(rng.integers(len(live)))]
                a.free(live.pop(h))
            else:
                want = int(rng.integers(0, n_blocks + 1))
                try:
                    blocks = a.alloc(want)
                except MemoryError:
                    assert want > a.n_free
                    continue
                live[len(live) + int(rng.integers(1 << 20))] = blocks
            held = [b for bs in live.values() for b in bs]
            assert len(held) == len(set(held)), "block double-assigned"
            assert a.n_free + len(held) == n_blocks, "block leaked"
        for h in list(live):
            a.free(live.pop(h))
        assert a.n_free == n_blocks


class TestPagedKVCache:
    def _kv(self, n_blocks=16, block_size=4, watermark=0.0):
        return PagedKVCache(KVCacheConfig(block_size=block_size,
                                          n_blocks=n_blocks,
                                          watermark=watermark))

    def test_committing_admission_extend_never_fails(self):
        kv = self._kv(n_blocks=4, block_size=4)
        assert kv.can_admit(10)      # 3 blocks
        kv.allocate(0, 10)
        kv.advance(0, 6)             # prompt
        for _ in range(4):           # 4 decode tokens inside reservation
            kv.extend(0)
        with pytest.raises(ValueError):
            kv.advance(0, 3)         # 13 > 12 rows: overran reservation
        kv.free_seq(0)
        assert kv.used_blocks == 0

    def test_watermark_holds_back_headroom(self):
        kv = self._kv(n_blocks=10, block_size=4, watermark=0.2)
        assert kv.can_admit(32)      # 8 blocks vs 10 - 2 reserve
        assert not kv.can_admit(36)  # 9 blocks breaches the floor
        kv.allocate(0, 32)
        assert not kv.can_admit(1)   # reserve floor holds at the margin

    def test_double_allocate_raises(self):
        kv = self._kv()
        kv.allocate(7, 4)
        with pytest.raises(ValueError):
            kv.allocate(7, 4)

    @settings(max_examples=25, deadline=None)
    @given(n_blocks=st.integers(2, 48), block_size=st.integers(1, 8),
           watermark=st.floats(0.0, 0.5), seed=st.integers(0, 2 ** 16))
    def test_no_leak_across_lifecycles(self, n_blocks, block_size,
                                       watermark, seed):
        """Admit/advance/extend/free request lifecycles at random: used
        blocks always equals the sum of live reservations, the watermark
        floor is never breached by admission, and draining every sequence
        returns the pool to empty."""
        rng = np.random.default_rng(seed)
        cfg = KVCacheConfig(block_size=block_size, n_blocks=n_blocks,
                            watermark=watermark)
        kv = PagedKVCache(cfg)
        floor = int(n_blocks * watermark)
        live = {}                    # seq_id -> total_tokens
        next_id = 0
        for _ in range(60):
            if live and rng.random() < 0.45:
                sid = list(live)[int(rng.integers(len(live)))]
                del live[sid]
                kv.free_seq(sid)
            else:
                total = int(rng.integers(1, 4 * block_size + 1))
                if kv.can_admit(total):
                    kv.allocate(next_id, total)
                    kv.advance(next_id, int(rng.integers(0, total + 1)))
                    live[next_id] = total
                    next_id += 1
                else:
                    assert (kv.allocator.n_free - floor
                            < cfg.blocks_for(total))
            expect = sum(cfg.blocks_for(t) for t in live.values())
            assert kv.used_blocks == expect, "leak or phantom allocation"
            assert kv.n_seqs == len(live)
        for sid in list(live):
            kv.free_seq(sid)
        assert kv.used_blocks == 0
        assert kv.utilization() == 0.0
        assert kv.peak_blocks <= n_blocks
