"""Performance-drift recalibration (§4.2.4's f_g refresh) + satellite fixes.

Covers the online perf-model pipeline end to end: time-varying ground truth
(VariabilityEvent schedules), telemetry buffering, residual detection,
window refits, controller recalibration — plus regression tests for the
engine capacity-charge budget fix, the migration virtual-clock charge, the
stress-precedence drift fix, the 0-knot anchor, and the benchmark's
shared-hardware-snapshot fix.
"""

import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import (DeviceProfile, DriftConfig, DriftDetector,
                        PerfDriftConfig, PerfDriftDetector, SCENARIOS,
                        TelemetryBuffer, ViBEConfig, ViBEController,
                        VariabilityEvent, fit_perf_model, make_cluster,
                        make_scenario, refit_from_samples)
from repro.serving.simulator import rank_latency_matrix

# compute-bound fixture dims (t_base negligible): drift in effective speed
# is visible in latency, as on the paper's real nodes
FIX = dict(d_model=1024, d_ff=512, experts_per_rank=8)


def _throttled_cluster(n=4, magnitude=0.3, t0=1.0, duration=2.0):
    events = make_scenario("thermal-ramp", n, t0=t0, duration=duration,
                           magnitude=magnitude)
    return make_cluster(n, "mi325x", events=events, **FIX)


class TestVariabilityEvents:
    def test_event_kinds_validate(self):
        with pytest.raises(ValueError):
            VariabilityEvent("meteor", 0.0, 0.1)
        with pytest.raises(ValueError):
            VariabilityEvent("step", 0.0, 1.5)           # not a fraction
        with pytest.raises(ValueError):
            VariabilityEvent("replace", 0.0, 0.9)        # needs a device
        VariabilityEvent("replace", 0.0, 0.9, device=0)  # ok

    def test_ramp_multiplier_shape(self):
        ev = VariabilityEvent("ramp", 1.0, 0.4, device=0, duration=2.0)
        assert ev.multiplier(0.5) == 1.0
        assert ev.multiplier(2.0) == pytest.approx(0.8)   # halfway
        assert ev.multiplier(10.0) == pytest.approx(0.6)  # holds after

    def test_transient_recovers(self):
        ev = VariabilityEvent("transient", 1.0, 0.3, device=2, duration=1.0)
        assert ev.multiplier(1.5) == pytest.approx(0.7)
        assert ev.multiplier(2.5) == 1.0

    def test_cluster_latency_time_varying_one_device(self):
        cl = _throttled_cluster()
        n = 4 * cl.n_tdp
        before, after = cl.latency(0, n, t=0.0), cl.latency(0, n, t=10.0)
        assert after > before * 1.2                   # ~30% throttle visible
        # other devices untouched
        assert cl.latency(1, n, t=10.0) == pytest.approx(
            cl.latency(1, n, t=0.0))

    def test_static_cluster_ignores_time(self):
        cl = make_cluster(4, "mi325x", **FIX)
        n = 4 * cl.n_tdp
        assert cl.latency(2, n, t=123.0) == pytest.approx(
            cl.latency(2, n, t=0.0))

    def test_rank_latency_matrix_matches_scalar_path(self):
        cl = _throttled_cluster()
        loads = np.array([[1000.0, 5000.0, 9000.0, 2.0 * cl.n_tdp]])
        for t in (0.0, 2.0, 8.0):
            mat = rank_latency_matrix(cl, loads, t=t)
            ref = [cl.latency(g, loads[0, g], t=t) for g in range(4)]
            np.testing.assert_allclose(mat[0], ref, rtol=1e-12)

    def test_replace_event_changes_intrinsic_bin(self):
        cl = make_cluster(4, "mi325x", events=make_scenario(
            "device-replace", 4, t0=1.0, magnitude=0.8), **FIX)
        n = 4 * cl.n_tdp
        assert cl.latency(0, n, t=5.0) > cl.latency(0, n, t=0.0)
        # replacement is stress-dependent: invisible at rest (Fig 5)
        assert cl.latency(0, 16, t=5.0) == pytest.approx(
            cl.latency(0, 16, t=0.0), rel=1e-4)

    def test_replace_events_resolve_by_time_not_list_order(self):
        cl = make_cluster(4, "mi325x", events=[
            VariabilityEvent("replace", 10.0, 0.9, device=0),
            VariabilityEvent("replace", 2.0, 0.7, device=0),
        ], **FIX)
        assert cl.base_speeds_at(5.0)[0] == pytest.approx(0.7)
        assert cl.base_speeds_at(11.0)[0] == pytest.approx(0.9)  # newest wins

    def test_scenario_registry(self):
        assert set(SCENARIOS) >= {"thermal-ramp", "power-cap",
                                  "interference", "device-replace"}
        with pytest.raises(ValueError):
            make_scenario("nope", 8)


class TestTelemetryBuffer:
    def test_window_and_samples(self):
        buf = TelemetryBuffer(2, window=4)
        buf.add(np.array([[1.0, 10.0], [2.0, 20.0]]),
                np.array([[0.1, 1.0], [0.2, 2.0]]))
        assert buf.count(0) == 2 and buf.count(1) == 2
        buf.add(np.full((3, 2), 5.0), np.full((3, 2), 0.5))
        assert buf.count(0) == 4                       # window evicts oldest
        n, lat = buf.samples(0)
        assert n[0] == 2.0 and lat[0] == 0.2           # oldest kept sample

    def test_shape_mismatch_raises(self):
        buf = TelemetryBuffer(3)
        with pytest.raises(ValueError):
            buf.add(np.ones(3), np.ones(2))
        with pytest.raises(ValueError):
            buf.add(np.ones(4), np.ones(4))            # wrong rank count

    def test_residuals_respect_min_samples(self):
        cl = make_cluster(2, "uniform", **FIX)
        models = cl.fit_models()
        buf = TelemetryBuffer(2, window=16)
        # 16384 is the top profiled knot, where the quantile-binned fit
        # is sharp — the residual then isolates the min_samples gating
        obs = np.array([cl.latency(0, 16384), cl.latency(1, 16384)])
        buf.add(np.array([16384.0, 16384.0]), obs)
        res = buf.relative_residuals(models, min_samples=4)
        assert np.isnan(res).all()
        for _ in range(4):
            buf.add(np.array([16384.0, 16384.0]), obs)
        res = buf.relative_residuals(models, min_samples=4)
        assert np.isfinite(res).all() and res.max() < 0.05


class TestZeroKnotAnchor:
    def test_fit_anchors_zero_knot(self):
        """Regression: docstring promises knots[0] == 0, but quantile knots
        started at the smallest sampled count (64), silently flat-clamping
        decode-scale loads through interp."""
        prof = DeviceProfile(0, np.array([64.0, 256, 1024, 4096, 16384]),
                             np.array([1e-3, 1.1e-3, 2e-3, 5e-3, 1.8e-2]))
        m = fit_perf_model(prof)
        assert m.knots[0] == 0.0
        # decode-scale loads see the memory-bound floor explicitly
        assert m(0) == pytest.approx(m(64))
        assert m(13) == pytest.approx(m(64))

    def test_refit_narrow_window_rescales_prior(self):
        """A saturated window (one operating point) keeps the prior's curve
        shape and rescales it — DVFS throttling is multiplicative."""
        prior = fit_perf_model(DeviceProfile(
            0, np.array([64.0, 1024, 4096, 16384]),
            np.array([1e-3, 2e-3, 6e-3, 2.2e-2])))
        n = np.full(12, 16000.0)
        lat = np.asarray(prior(n)) * 1.5
        m = refit_from_samples(n, lat, prior=prior)
        np.testing.assert_allclose(m.knots, prior.knots)
        np.testing.assert_allclose(m.lat, prior.lat * 1.5, rtol=1e-9)
        # a diverse window refits the shape from data instead
        n2 = np.array([100.0, 1000, 4000, 16000.0] * 3)
        m2 = refit_from_samples(n2, np.asarray(prior(n2)), prior=prior)
        assert m2.knots.size != prior.knots.size \
            or not np.allclose(m2.knots, prior.knots)


class TestStressAwareRefit:
    """Narrow-window refits distinguish the two physical drifts: a power
    cap (DVFS) divides the whole kernel — flat observed/predicted ratio →
    multiplicative rescale — while a stress-gated deviation inflates only
    the load-dependent region → floor-preserving shape refit."""

    def _prior(self):
        return fit_perf_model(DeviceProfile(
            0, np.array([64.0, 1024, 4096, 16384]),
            np.array([1e-3, 2e-3, 6e-3, 2.2e-2])))

    def test_power_cap_rescales_whole_curve(self):
        """Regression (power-cap): a capped rank under near-saturated
        load must come back as prior * factor — knots untouched, and the
        decode-scale floor scaled too, because the cap slows the whole
        kernel, not just the high-load region."""
        prior = self._prior()
        rng = np.random.default_rng(0)
        n = rng.uniform(12_000, 16_000, 16)      # span < min_span
        m = refit_from_samples(n, np.asarray(prior(n)) * 1.4, prior=prior)
        np.testing.assert_allclose(m.knots, prior.knots)
        np.testing.assert_allclose(m.lat, prior.lat * 1.4, rtol=1e-9)
        assert float(m(0)) == pytest.approx(float(prior(0)) * 1.4,
                                            rel=1e-6)

    def test_deviation_preserves_floor(self):
        """A load-dependent inflation (ratio rising with load) must NOT
        drag the memory-bound floor up: lat' = floor + k*(prior - floor)."""
        prior = self._prior()
        floor = float(prior.lat[0])
        rng = np.random.default_rng(1)
        n = rng.uniform(520, 2040, 24)           # span ~3.9 < min_span,
        pred = np.asarray(prior(n))              # floor is ~1/2 of pred
        m = refit_from_samples(n, floor + (pred - floor) * 1.8,
                               prior=prior)
        np.testing.assert_allclose(m.knots, prior.knots)
        np.testing.assert_allclose(m.lat, floor + 1.8 * (prior.lat - floor),
                                   rtol=1e-9)
        # low-load predictions untouched by a drift that never hit them
        assert float(m(0)) == pytest.approx(floor, rel=1e-9)
        assert float(m(64)) == pytest.approx(float(prior(64)), rel=1e-9)


class TestPerfDriftDetector:
    def _setup(self, **cfg):
        cl = _throttled_cluster(magnitude=0.35, t0=0.0, duration=0.5)
        models = cl.fit_models()                       # profiled at t=0
        kw = dict(delta_perf=0.12, window=64, interval=5, cooldown=10,
                  min_samples=8)
        kw.update(cfg)
        det = PerfDriftDetector(4, models, PerfDriftConfig(**kw))
        return cl, det

    def _feed(self, cl, det, t, steps, rng):
        events = []
        for _ in range(steps):
            loads = rng.uniform(2000, 9000, size=(3, 4))
            lats = np.array([[cl.latency(g, loads[l, g], t=t, jitter=True)
                              for g in range(4)] for l in range(3)])
            ev = det.observe(loads, lats)
            if ev is not None:
                events.append(ev)
        return events

    def test_no_fire_when_models_match(self):
        cl, det = self._setup()
        assert self._feed(cl, det, 0.0, 40, np.random.default_rng(0)) == []

    def test_fires_on_throttled_rank(self):
        cl, det = self._setup()
        events = self._feed(cl, det, 5.0, 20, np.random.default_rng(1))
        assert events and events[0].kind == "perf"
        assert 0 in events[0].ranks                    # the ramped device
        assert events[0].max_residual > 0.12
        assert events[0].rank_residuals[0] > 0.12

    def test_refires_until_snapshot_then_cools_down(self):
        cl, det = self._setup(cooldown=100)
        rng = np.random.default_rng(2)
        events = self._feed(cl, det, 5.0, 12, rng)
        assert events                     # refires every interval while hot
        det.snapshot()                    # recalibration done → cool down
        assert self._feed(cl, det, 5.0, 60, rng) == []

    def test_refit_round_trip_clears_residual(self):
        """Refit from the window on a throttled cluster: the refreshed f_g
        tracks the drifted ground truth within the jitter band and the
        residual signal drops back below threshold."""
        cl, det = self._setup()
        events = self._feed(cl, det, 5.0, 20, np.random.default_rng(3))
        assert events
        refit = det.refit(events[0].ranks)
        assert 0 in refit
        assert det.residuals().max() < 0.12            # signal cleared
        grid = np.linspace(2000, 9000, 13)
        truth = np.array([cl.latency(0, n, t=5.0) for n in grid])
        pred = np.asarray(det.models[0](grid))
        assert (np.abs(pred - truth) / truth).max() < 0.10


class TestStressPrecedence:
    """Regression: simultaneous magnitude surge + routing drift must take
    the stress (full re-solve) path, not the incremental routing path."""

    def _warm(self, det, base, tokens=4096, steps=40):
        for _ in range(steps):
            det.observe(base, tokens)

    def test_simultaneous_drift_reports_stress(self):
        rng = np.random.default_rng(0)
        det = DriftDetector(4, 16, DriftConfig(window=20, interval=5))
        base = rng.dirichlet(np.full(16, 0.3), size=4) * 4096
        self._warm(det, base)
        shifted = np.roll(base, 5, axis=1) * 4.0       # both signals at once
        fired = [e for e in (det.observe(shifted, 4 * 4096)
                             for _ in range(40)) if e is not None]
        assert fired and fired[0].kind == "stress"
        assert fired[0].routing_drift                  # both signals carried
        assert fired[0].layer >= 0
        assert fired[0].max_cos_distance > 0.05

    def test_pure_stress_has_no_routing_layer(self):
        rng = np.random.default_rng(1)
        det = DriftDetector(4, 16, DriftConfig(window=20, interval=5))
        base = rng.dirichlet(np.full(16, 0.3), size=4) * 4096
        self._warm(det, base)
        fired = [e for e in (det.observe(base * 4, 4 * 4096)
                             for _ in range(40)) if e is not None]
        assert fired and fired[0].kind == "stress"
        assert not fired[0].routing_drift and fired[0].layer == -1

    def test_controller_full_resolves_on_simultaneous_drift(self):
        cl = make_cluster(4, "mi325x", **FIX)
        ctl = ViBEController(
            3, 16, 4, cl.fit_models(),
            ViBEConfig(policy="vibe", adaptive=True, expert_bytes=100,
                       drift=DriftConfig(window=10, interval=5, cooldown=5)))
        rng = np.random.default_rng(2)
        base = rng.dirichlet(np.full(16, 0.3), size=3) * 4096
        for _ in range(20):
            ctl.observe(base)
        shifted = np.roll(base, 6, axis=1) * 4.0
        upds = [u for u in (ctl.observe(shifted) for _ in range(30))
                if u is not None]
        assert upds and upds[0].kind == "stress"
        assert upds[0].full_resolve                    # not the swap path


class TestControllerPerfRecalibration:
    def _controller(self, cl, **kw):
        kw.setdefault("policy", "vibe")
        kw.setdefault("adaptive", True)
        kw.setdefault("expert_bytes", 1000)
        kw.setdefault("perf_drift", PerfDriftConfig(
            delta_perf=0.12, window=64, interval=5, cooldown=5,
            min_samples=8))
        return ViBEController(3, 16, 4, cl.fit_models(), ViBEConfig(**kw))

    def _feed_latency(self, cl, ctl, t, steps, seed=0):
        rng = np.random.default_rng(seed)
        upds = []
        for _ in range(steps):
            loads = rng.uniform(2000, 9000, size=(3, 4))
            lats = np.array([[cl.latency(g, loads[l, g], t=t, jitter=True)
                              for g in range(4)] for l in range(3)])
            u = ctl.observe_latency(loads, lats)
            if u is not None:
                upds.append(u)
        return upds

    def test_perf_event_refits_and_recalibrates(self):
        cl = _throttled_cluster(magnitude=0.35, t0=0.0, duration=0.5)
        ctl = self._controller(cl)
        stale_pred = ctl.perf_models[0](8000)
        upds = self._feed_latency(cl, ctl, 5.0, 30)
        assert upds, "perf drift never recalibrated"
        u = upds[0]
        assert u.kind == "perf" and u.full_resolve
        assert 0 in u.refit_ranks
        assert ctl.updates and ctl.updates[0] is u
        # the shared models list was refreshed in place
        new_pred = ctl.perf_models[0](8000)
        truth = cl.latency(0, 8000, t=5.0)
        assert abs(new_pred - truth) / truth < abs(stale_pred - truth) / truth
        assert abs(new_pred - truth) / truth < 0.08

    def test_incremental_path_when_full_resolve_disabled(self):
        cl = _throttled_cluster(magnitude=0.35, t0=0.0, duration=0.5)
        ctl = self._controller(cl, full_resolve_on_stress=False)
        upds = self._feed_latency(cl, ctl, 5.0, 30)
        assert upds and not upds[0].full_resolve
        assert upds[0].swaps_per_layer is not None

    def test_static_controller_tracks_but_never_updates(self):
        cl = _throttled_cluster(magnitude=0.35, t0=0.0, duration=0.5)
        ctl = self._controller(cl, adaptive=False)
        assert self._feed_latency(cl, ctl, 5.0, 30) == []
        # telemetry still recorded for A/B stat parity
        assert ctl.perf_detector.events
        assert ctl.perf_detector.buffer.count(0) > 0

    def test_no_detector_without_config(self):
        cl = make_cluster(4, "mi325x", **FIX)
        ctl = ViBEController(3, 16, 4, cl.fit_models(),
                             ViBEConfig(policy="vibe"))
        assert ctl.perf_detector is None
        assert ctl.observe_latency(np.ones(4), np.ones(4)) is None

    def test_perf_drift_requires_perf_model_policy(self):
        with pytest.raises(ValueError, match="needs_perf_models"):
            ViBEConfig(policy="eplb", perf_drift=PerfDriftConfig())


class TestBenchSharedSnapshot:
    """Regression: fig11's A/B arms must score one hardware snapshot —
    fit_models() draws from the cluster's jitter RNG, so per-arm profiling
    hands each arm different models."""

    def test_fit_models_advances_jitter_rng(self):
        cl = make_cluster(4, "mi325x", **FIX)
        a, b = cl.fit_models(), cl.fit_models()
        assert any(not np.allclose(x.lat, y.lat) for x, y in zip(a, b))

    def test_fig11_arms_share_one_snapshot(self):
        from benchmarks.bench_fig11_drift import _placement, _sim
        from benchmarks.common import paper_cluster, profile_W
        model = "deepseek-v3-671b"
        cluster = paper_cluster(model, "mi325x")
        perf = cluster.fit_models()
        W0 = profile_W(model, "sonnet")
        static_pl = _placement("vibe", W0, cluster, perf)
        sim = _sim(model, "sonnet", "sharegpt", "vibe", True, cluster, perf)
        np.testing.assert_array_equal(
            sim.controller.placement.slot_expert, static_pl.slot_expert)


class TestEngineAccounting:
    def test_capacity_charge_uses_per_rank_budget(self):
        """Regression: the capacity virtual clock priced every rank
        n_slots // G × cap rows, ignoring non-uniform per-rank slot
        budgets; it must read the placement's real bucket counts."""
        import types
        from repro.serving.engine import Engine, EngineStats
        from repro.serving.simulator import capacity_bucket_rows
        cl = make_cluster(4, "mi325x", **FIX)
        budget = [6, 4, 4, 4]
        ctl = ViBEController(
            2, 16, 4, cl.fit_models(),
            ViBEConfig(policy="vibe_r", slot_budget=budget))
        eng = Engine.__new__(Engine)           # pricing path only — no jit
        eng.cfg = types.SimpleNamespace(is_moe=True, top_k=2, n_experts=16)
        eng.rules = None
        eng.moe_impl = "capacity"
        eng.cluster = cl
        eng.controller = ctl
        eng.n_slots = ctl.placement.n_slots
        eng.stats = EngineStats()
        rb = ctl.placement.rank_slot_budget()
        assert rb.min() != rb.max()            # genuinely non-uniform
        tallies = np.ones((2, 17))             # (L, E+1) with drop column
        tokens = 512
        dt = eng._charge(tallies, tokens)
        cap = capacity_bucket_rows(tokens, 2, eng.n_slots, 1.25)
        want = rank_latency_matrix(cl, rb.astype(float) * cap,
                                   t=0.0).max(1).sum()
        assert dt == pytest.approx(float(want))
        # the old flat pricing (n_slots // G per rank) is measurably wrong
        s_loc = eng.n_slots // 4
        flat = rank_latency_matrix(
            cl, np.full((2, 4), float(s_loc * cap)), t=0.0).max(1).sum()
        assert dt != pytest.approx(float(flat))

    def _engine(self, cfg_kw=(), cluster_kw=(), arch="qwen3-moe-235b-a22b"):
        from repro.configs import get_smoke
        from repro.models import moe_perm_shape
        from repro.serving import Engine
        cfg = get_smoke(arch)
        n_moe, n_slots = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=1024, d_ff=512,
                               experts_per_rank=max(n_slots // 4, 1),
                               **dict(cluster_kw))
        ctl = ViBEController(
            n_moe, n_slots, 4, cluster.fit_models(),
            ViBEConfig(policy="vibe", expert_bytes=3 * cfg.d_model
                       * cfg.moe_d_ff * 2, **dict(cfg_kw)))
        return Engine(cfg, controller=ctl, cluster=cluster,
                      max_batch=2, max_seq=48, seed=0)

    def test_migration_charges_virtual_clock(self):
        """Regression: engine recalibrations accrued migration_bytes but
        never advanced virtual_time, hiding migration stalls from
        engine-measured TTFT."""
        eng = self._engine()
        rng = np.random.default_rng(0)
        perm = np.stack([rng.permutation(eng.n_slots)
                         for _ in range(eng.n_moe)]).astype(np.int32)
        vt0, bytes0 = eng.stats.virtual_time, eng.stats.migration_bytes
        moved = eng._apply_perm(perm)
        assert moved > 0
        moved_bytes = eng.stats.migration_bytes - bytes0
        assert moved_bytes > 0
        assert eng.stats.virtual_time - vt0 == pytest.approx(
            moved_bytes / eng.cluster.ici_bw)

    def test_engine_perf_drift_recalibrates_end_to_end(self):
        """The full feedback loop on real routing: virtual-clock telemetry →
        perf-drift event → refit → re-solve → weight migration, all inside
        the serving engine."""
        from repro.serving import WORKLOADS, sample_requests
        eng = self._engine(
            cfg_kw=dict(adaptive=True,
                        drift=DriftConfig(window=200, interval=10,
                                          cooldown=10),
                        perf_drift=PerfDriftConfig(
                            delta_perf=0.25, window=64, interval=3,
                            cooldown=4, min_samples=6)),
            # rank 0 halves speed just after profiling: a multiplicative
            # step is visible even at decode-scale loads
            cluster_kw=dict(events=[VariabilityEvent("step", 1e-9, 0.5,
                                                     device=0)],
                            t_base=1e-7))
        reqs = sample_requests(WORKLOADS["sharegpt"], 4, qps=100.0, seed=0)
        reqs = [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]
        eng.submit(reqs)
        records = eng.run(max_steps=200)
        done = [r for r in records if np.isfinite(r.finished_at)]
        assert len(done) == 4
        perf_upds = [u for u in eng.controller.updates if u.kind == "perf"]
        assert perf_upds, "engine telemetry never triggered a perf refresh"
        assert 0 in perf_upds[0].refit_ranks
        assert eng.stats.migrations >= 1
        # refreshed rank-0 model reflects the halved speed
        pred = eng.controller.perf_models[0](64)
        truth = eng.cluster.latency(0, 64, t=1.0)
        assert abs(pred - truth) / truth < 0.15


@pytest.mark.slow
class TestThermalRampRecovery:
    """Acceptance: on a thermal-ramp scenario, adaptive ViBE with perf-drift
    recalibration recovers ≥ half of the goodput gap between the stale-model
    run and an oracle re-solved with fresh models."""

    def test_recovers_half_the_goodput_gap(self):
        from benchmarks.bench_fig11_drift import (_hw_cluster, _placement,
                                                  EXPERT_BYTES)
        from benchmarks.common import profile_W
        from repro.configs import get
        from repro.serving import (EPSimulator, PAPER_SLOS, SimConfig,
                                   WORKLOADS, goodput, sample_requests)
        model = "deepseek-v3-671b"
        m = get(model)
        W0 = profile_W(model, "sonnet")
        slo = PAPER_SLOS[("sonnet", model)]
        t0, dur, t_end = 1.0, 2.0, 5.0
        reqs = sample_requests(WORKLOADS["sonnet"], 300, qps=40.0, seed=4)
        gps, ctl = {}, None
        for arm in ("stale", "adaptive", "oracle"):
            cl = _hw_cluster(model, "thermal-ramp", t0, dur)
            perf = cl.fit_models(t=t_end if arm == "oracle" else 0.0)
            cfg = SimConfig(ep_degree=8, seed=3, max_prefill_tokens=16_384)
            if arm == "adaptive":
                ctl = ViBEController(
                    m._n_moe_layers(), m.n_experts, 8, perf,
                    ViBEConfig(policy="vibe", adaptive=True,
                               drift=DriftConfig(window=50, interval=10,
                                                 cooldown=20),
                               perf_drift=PerfDriftConfig(
                                   delta_perf=0.08, window=128, interval=5,
                                   cooldown=10, min_samples=16),
                               full_resolve_on_stress=False,
                               expert_bytes=EXPERT_BYTES(m)),
                    initial_w=W0)
                sim = EPSimulator(m, cl, WORKLOADS["sonnet"], cfg,
                                  controller=ctl)
                adaptive_cl = cl
            else:
                sim = EPSimulator(m, cl, WORKLOADS["sonnet"], cfg,
                                  placement=_placement("vibe", W0, cl, perf))
            gps[arm] = goodput(sim.run(reqs, phase="prefill"), slo)
        gap = gps["oracle"] - gps["stale"]
        assert gap > 0.1, f"scenario shows no stale-vs-oracle gap: {gps}"
        recovered = (gps["adaptive"] - gps["stale"]) / gap
        assert recovered >= 0.5, f"recovered only {recovered:.2f}: {gps}"
        # the refreshed f_g tracks the drifted ground truth on the refit
        # ranks (rank 0 is the ramped device) over the load range the rank
        # actually served — an online refit is only ever valid over its
        # telemetry window. The absolute band is set by the piecewise fit's
        # knee-binning error (~10%, same as a fresh Phase-1 fit there), so
        # the sharp claim is comparative: the refresh removes the ~45%
        # staleness error the frozen model carries.
        perf_upds = [u for u in ctl.updates if u.kind == "perf"]
        assert perf_upds and any(0 in u.refit_ranks for u in perf_upds)
        n_win, _ = ctl.perf_detector.buffer.samples(0)
        lo, hi = np.quantile(n_win, [0.1, 0.9])
        grid = np.linspace(lo, hi, 9)
        truth = np.array([adaptive_cl.latency(0, n, t=10.0) for n in grid])
        pred = np.asarray(ctl.perf_models[0](grid))
        rel = np.abs(pred - truth) / truth
        stale_cl = _hw_cluster(model, "thermal-ramp", t0, dur)
        stale_rel = np.abs(np.asarray(
            stale_cl.fit_models()[0](grid)) - truth) / truth
        assert np.median(rel) < 0.15
        assert np.median(rel) < 0.6 * np.median(stale_rel)
