"""Elastic serving: the engine survives losing an EP rank mid-traffic.

The drill (drain → masked re-solve → remap → re-admit) is the PR's
acceptance invariant: every admitted request completes, no KV block
leaks, and the dead rank stops receiving dispatch — at the cost of a
bounded goodput dip, not an outage.
"""

import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import DriftConfig, ViBEConfig, ViBEController, make_cluster
from repro.serving import (Engine, WORKLOADS, fail_rank, goodput,
                           run_with_failure, sample_requests, SLO)


def _engine(policy="vibe_r", arch="qwen3-moe-235b-a22b"):
    cfg = get_smoke(arch)
    from repro.models import moe_perm_shape
    n_moe, n_slots = moe_perm_shape(cfg, None, "train")
    cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff,
                           experts_per_rank=n_slots // 4)
    ctl = ViBEController(
        n_moe, n_slots, 4, cluster.fit_models(),
        ViBEConfig(policy=policy, adaptive=True,
                   drift=DriftConfig(window=8, interval=4, cooldown=4),
                   expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
    return Engine(cfg, controller=ctl, cluster=cluster,
                  max_batch=2, max_seq=48, seed=0)


def _short_requests(n, seed=0):
    reqs = sample_requests(WORKLOADS["sharegpt"], n, qps=100.0, seed=seed)
    return [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]


@pytest.fixture(scope="module")
def drill():
    """One engine run with rank 1 killed mid-traffic, shared across the
    invariant checks (engine construction jits the smoke model — seconds,
    not milliseconds). max_batch=2 on G=4 means only lanes 0 and 1 exist;
    rank 1 owns lane 1 (lane b lives on rank b % G), so killing it drains
    real in-flight state."""
    eng = _engine()
    records, report = run_with_failure(eng, _short_requests(6), rank=1,
                                       at_step=4, max_steps=400)
    return eng, records, report


class TestFailureDrill:
    def test_all_admitted_requests_complete(self, drill):
        eng, records, report = drill
        assert report is not None and report.rank == 1
        assert len(records) == 6
        assert all(np.isfinite(r.finished_at) for r in records)
        assert all(r.ttft >= 0 for r in records)

    def test_no_leaked_kv_blocks(self, drill):
        eng, _, _ = drill
        assert eng.kv.used_blocks == 0

    def test_drain_was_real_and_tallied(self, drill):
        _, _, report = drill
        assert report.drained_prefills + report.drained_decodes >= 1
        assert report.redone_tokens >= 1

    def test_dead_rank_masked_out_of_dispatch(self, drill):
        eng, _, _ = drill
        ctl = eng.controller
        assert ctl.dead_ranks == (1,)
        pl = ctl.placement
        spr = pl.slots_per_rank
        dead_window = pl.share[:, 1 * spr:2 * spr]
        np.testing.assert_allclose(dead_window, 0.0)
        # survivors carry the full share mass
        np.testing.assert_allclose(
            pl.rank_loads(np.ones((ctl.L, ctl.E)))[:, 1], 0.0)

    def test_fail_event_recorded_as_full_resolve(self, drill):
        eng, _, report = drill
        fails = [u for u in eng.controller.updates if u.kind == "fail"]
        assert len(fails) == 1
        assert fails[0].full_resolve
        assert fails[0].moved_experts == report.moved_experts
        assert report.migration_bytes == \
            report.moved_experts * eng.controller.cfg.expert_bytes

    def test_bounded_goodput_dip(self, drill):
        """Failure costs throughput, not correctness: with generous SLOs
        the drill still lands every request; with the TTFT bar at the
        recovery stall the dip is visible but bounded (not an outage)."""
        _, records, _ = drill
        assert goodput(records, SLO(ttft=1e9, tpot=1e9)) == 1.0
        assert goodput(records, SLO(ttft=np.median(
            [r.ttft for r in records]) + 1e-9, tpot=1e9)) >= 0.5


class TestFailRankEdges:
    def test_already_dead_rank_raises(self, drill):
        eng, _, _ = drill
        with pytest.raises(ValueError, match="already dead"):
            fail_rank(eng, 1)

    def test_out_of_range_rank_raises(self, drill):
        eng, _, _ = drill
        with pytest.raises(ValueError, match="outside"):
            fail_rank(eng, 7)

    def test_controllerless_engine_raises(self):
        cfg = get_smoke("qwen3-moe-235b-a22b")
        eng = Engine(cfg, max_batch=2, max_seq=48, seed=0)
        with pytest.raises(ValueError, match="controller"):
            fail_rank(eng, 0)

    def test_second_failure_on_survivor(self, drill):
        """A second loss on the already-degraded fleet still drains and
        re-solves (survivor budgets permitting)."""
        eng, _, _ = drill
        report = fail_rank(eng, 0)
        assert eng.controller.dead_ranks == (0, 1)
        assert report.rank == 0
        records = eng.run(max_steps=200)
        assert all(np.isfinite(r.finished_at) for r in records)
        assert eng.kv.used_blocks == 0
