"""ViBE-R: replication invariants, solver vectorization, model semantics."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ReplicatedPlacement, default_slots_per_rank,
                        incremental_update_replicated, layer_latency_span,
                        make_cluster, predicted_rank_latencies,
                        solve_model_placement, vibe_placement,
                        vibe_r_placement)
from repro.core.placement import (_greedy_target_assign,
                                  _greedy_target_assign_vec, _speed_targets)


def zipf_loads(rng, L, E, alpha=1.2, tokens=200_000.0):
    z = 1.0 / np.arange(1, E + 1) ** alpha
    prof = np.stack([rng.permutation(z) for _ in range(L)])
    return prof / prof.sum(axis=1, keepdims=True) * tokens


def paper_perf(G, seed=0, **kw):
    cluster = make_cluster(G, "mi325x", d_model=1024, d_ff=512,
                           experts_per_rank=8, seed=seed, **kw)
    return cluster.fit_models()


# ---------------------------------------------------------------------------
# solver vectorization equivalence
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n_ranks=st.sampled_from([2, 4, 8]),
       e_per=st.integers(1, 6), n_layers=st.integers(1, 5))
def test_vectorized_greedy_matches_perlayer_reference(seed, n_ranks, e_per,
                                                      n_layers):
    """The layer-vectorized greedy fill is a pure reimplementation of the
    per-layer reference loop: identical assignment, bit for bit."""
    E = n_ranks * e_per
    rng = np.random.default_rng(seed)
    w = rng.random((n_layers, E)) * 1000
    targets = rng.random((n_layers, n_ranks)) \
        * w.sum(1, keepdims=True) / n_ranks * 2
    vec = _greedy_target_assign_vec(w, targets)
    ref = np.stack([_greedy_target_assign(w[l], targets[l].copy(), n_ranks)
                    for l in range(n_layers)])
    np.testing.assert_array_equal(vec, ref)


def test_vibe_solver_matches_legacy_perlayer_path():
    """vibe_placement (vectorized) == per-layer greedy over speed targets."""
    G = 8
    perf = paper_perf(G)
    rng = np.random.default_rng(3)
    w = rng.dirichlet(np.full(64, 0.3), size=6) * 50_000
    pl = vibe_placement(w, perf)
    _, targets = _speed_targets(w, perf, "rank")
    ref = np.stack([_greedy_target_assign(w[l], targets[l].copy(), G)
                    for l in range(6)])
    np.testing.assert_array_equal(pl.assign, ref)


# ---------------------------------------------------------------------------
# replication invariants (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 1000), n_ranks=st.sampled_from([2, 4, 8]),
       e_per=st.integers(1, 4), extra=st.integers(0, 3),
       n_layers=st.integers(1, 4))
def test_every_expert_placed_and_slot_budget_respected(seed, n_ranks, e_per,
                                                       extra, n_layers):
    E = n_ranks * e_per
    s_loc = e_per + extra
    if s_loc > E:
        s_loc = E
    rng = np.random.default_rng(seed)
    w = rng.random((n_layers, E)) * 1000 + 1e-6
    models = paper_perf(n_ranks, seed=seed % 7)
    rp = vibe_r_placement(w, models, slots_per_rank=s_loc)
    # slot budget: exactly slots_per_rank × G physical slots, rank-major
    assert rp.n_slots == s_loc * n_ranks
    assert rp.slots_per_rank == s_loc
    # every logical expert holds ≥ 1 copy, shares sum to 1 per expert
    nc = rp.n_copies()
    assert nc.shape == (n_layers, E)
    assert (nc >= 1).all()
    assert int(nc.sum()) == rp.n_slots * n_layers
    # traffic conservation: splitting over copies never loses tokens
    np.testing.assert_allclose(rp.rank_loads(w).sum(1), w.sum(1))


def test_copies_never_colocated_on_one_rank():
    """A replica on the rank that already holds its sibling absorbs no
    skew; the greedy must spread copies across ranks."""
    rng = np.random.default_rng(0)
    G, E = 8, 32
    w = zipf_loads(rng, 4, E)
    rp = vibe_r_placement(w, paper_perf(G), slots_per_rank=6)
    L, S = rp.slot_expert.shape
    s_loc = rp.slots_per_rank
    for l in range(L):
        per_rank = rp.slot_expert[l].reshape(G, s_loc)
        for g in range(G):
            assert len(set(per_rank[g])) == s_loc, (l, g)


def test_replicated_placement_validation():
    with pytest.raises(ValueError):   # expert 1 has no slot
        ReplicatedPlacement(np.array([[0, 0]]), np.array([[0.5, 0.5]]),
                            n_ranks=2, n_experts=2)
    with pytest.raises(ValueError):   # shares don't sum to 1
        ReplicatedPlacement(np.array([[0, 1]]), np.array([[0.5, 0.5]]),
                            n_ranks=2, n_experts=2)
    with pytest.raises(ValueError):   # budget cannot hold every expert
        vibe_r_placement(np.ones((1, 8)), paper_perf(2), slots_per_rank=3)


def test_default_slots_per_rank():
    assert default_slots_per_rank(64, 8) == 9       # even split → +1 spare
    assert default_slots_per_rank(40, 16) == 3      # ceil(40/16) padding
    assert default_slots_per_rank(6, 4) == 2


# ---------------------------------------------------------------------------
# latency objective: replication beats singleton on skew
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 200))
def test_replicated_span_never_worse_than_singleton_on_skew(seed):
    """Paper Fig 15 regime: on Zipf-skewed loads the replicated solution's
    predicted max-layer latency is at most singleton ViBE's (the extra
    slots strictly add placement freedom)."""
    rng = np.random.default_rng(seed)
    G, E, L = 8, 64, 4
    perf = paper_perf(G, seed=seed % 5)
    w = zipf_loads(rng, L, E)
    span_r = layer_latency_span(
        vibe_r_placement(w, perf, slots_per_rank=E // G + 1), w, perf)
    span_v = layer_latency_span(vibe_placement(w, perf), w, perf)
    assert span_r[:, 0].max() <= span_v[:, 0].max() * 1.01


def test_replication_strictly_helps_on_hot_expert():
    """One mega-hot expert pins a singleton placement; copies split it."""
    G, E, L = 4, 16, 2
    perf = paper_perf(G)
    w = np.full((L, E), 100.0)
    w[:, 0] = 50_000.0
    rp = vibe_r_placement(w, perf, slots_per_rank=E // G + 2)
    pv = vibe_placement(w, perf)
    r = layer_latency_span(rp, w, perf)[:, 0].mean()
    v = layer_latency_span(pv, w, perf)[:, 0].mean()
    assert r < 0.7 * v
    assert rp.n_copies()[:, 0].min() >= 2     # the hot expert got replicas


# ---------------------------------------------------------------------------
# incremental updates over (expert, copy) slots
# ---------------------------------------------------------------------------

class TestIncrementalReplicated:
    def setup_method(self):
        self.perf = paper_perf(8, seed=1)
        rng = np.random.default_rng(4)
        self.w0 = zipf_loads(rng, 5, 64)
        self.w1 = np.roll(self.w0, 9, axis=1)
        self.rp = vibe_r_placement(self.w0, self.perf, slots_per_rank=9)

    def test_never_increases_max_latency(self):
        res = incremental_update_replicated(self.rp, self.w1, self.perf)
        before = predicted_rank_latencies(self.rp, self.w1, self.perf).max(1)
        after = predicted_rank_latencies(res.placement, self.w1,
                                         self.perf).max(1)
        assert (after <= before + 1e-12).all()

    def test_invariants_preserved_and_moves_are_slots(self):
        res = incremental_update_replicated(self.rp, self.w1, self.perf)
        new = res.placement
        assert isinstance(new, ReplicatedPlacement)   # re-validated on build
        # replica counts are swap-invariant (copies move, never (dis)appear)
        np.testing.assert_array_equal(new.n_copies(), self.rp.n_copies())
        assert new.moved_experts(self.rp) == 2 * len(res.swaps)
        assert res.per_layer_swaps.sum() == len(res.swaps)


# ---------------------------------------------------------------------------
# solve_model_placement plumbing
# ---------------------------------------------------------------------------

def test_solve_model_placement_vibe_r_dispatch():
    w = np.ones((2, 8))
    perf = paper_perf(4)
    rp = solve_model_placement("vibe_r", w, 4, perf_models=perf)
    assert isinstance(rp, ReplicatedPlacement)
    assert rp.slots_per_rank == default_slots_per_rank(8, 4)
    with pytest.raises(ValueError):
        solve_model_placement("vibe_r", w, 4)         # needs perf models
    with pytest.raises(ValueError):
        solve_model_placement("vibe_r", w, 2, perf_models=perf)  # G mismatch


# ---------------------------------------------------------------------------
# model layer: replicated slot table is semantically invisible
# ---------------------------------------------------------------------------

def test_moe_layer_replicated_slot_table_semantics():
    """Dispatching through a ViBE-R slot table (copies of hot experts in
    the spare slots) must produce the same outputs and router tallies as
    the singleton identity layout — replicas only redistribute load."""
    import jax
    import jax.numpy as jnp
    from repro.models import moe as MOE
    from repro.models.sharding import build_slots_of

    E, D, F, K, G = 8, 32, 64, 2, 4
    p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, D)) \
        .astype(jnp.bfloat16)
    y_ref, tally_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E,
                                        rules=None)

    w = np.full((1, E), 10.0)
    w[0, 0] = 1000.0
    rp = vibe_r_placement(w, paper_perf(G), slots_per_rank=3)   # S=12 > E=8
    perm = rp.perm[0]
    p_rep = dict(p)
    for k in ("w1", "w2", "w3"):
        p_rep[k] = p[k][perm]                       # slot p ← expert perm[p]
    slots_of, n_copies = build_slots_of(rp.perm, E, rp.n_slots)
    y, tally, _ = MOE.moe_layer(p_rep, x, top_k=K, n_experts=E, rules=None,
                                slots_of=jnp.asarray(slots_of[0]),
                                n_copies=jnp.asarray(n_copies[0]))
    err = float(jnp.abs(y_ref.astype(jnp.float32)
                        - y.astype(jnp.float32)).max())
    assert err < 1e-5, err
    np.testing.assert_allclose(np.asarray(tally_ref), np.asarray(tally))
