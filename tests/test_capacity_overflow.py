"""Capacity-overflow coverage for the EP dispatch paths (models/moe.py).

The a2a path buckets assignments per physical slot with a fixed capacity;
assignments past a bucket's capacity are dropped. These tests pin the two
contracts of that drop path:

* **conservation** — dropped assignments contribute exactly zero to the
  combined output; kept assignments keep their unmodified gate weights
  (verified against a from-scratch numpy/jnp reference that replays the
  bucketing);
* **visibility** — the drop count is surfaced in the layer tally's final
  column (and aggregated into ``EngineStats.dropped_assignments``) instead
  of being silently zeroed.

Runs in-process on a 1-device mesh, so the fast CI lane covers the real
``shard_map`` dispatch bodies without the multi-process battery.

Since the ragged dropless pipeline became the default (``moe_impl="auto"``
→ ragged, which structurally cannot drop), these tests pin
``moe_impl="capacity"`` explicitly — they are the capacity baseline's
regression suite. The ragged path's no-drop contract is covered in
``test_ragged_dispatch.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.models import moe as MOE
from repro.models.sharding import ShardingRules

E, D, F, K = 4, 16, 64, 2
B, S = 2, 16


def _bucket_keep(slot_flat, n_slots, capacity):
    """Replay of ``_bucket_positions``: arrival order within each bucket."""
    pos = np.zeros_like(slot_flat)
    fill = np.zeros(n_slots, dtype=np.int64)
    for i, s in enumerate(slot_flat):
        pos[i] = fill[s]
        fill[s] += 1
    return pos < capacity


def _reference_with_drops(p, x, capacity):
    """Dense oracle with the a2a keep mask applied by hand."""
    xf = np.asarray(x.reshape(B * S, D), np.float32)
    weights, idx, _ = MOE.route(p["router"], jnp.asarray(xf), K)
    weights, idx = np.asarray(weights), np.asarray(idx)
    keep = _bucket_keep(idx.reshape(-1), E, capacity).reshape(idx.shape)
    y_all = np.asarray(MOE.expert_ffn_ref(
        p["w1"], p["w3"], p["w2"],
        jnp.broadcast_to(jnp.asarray(xf, x.dtype), (E, B * S, D))),
        np.float32)
    out = np.zeros((B * S, D), np.float32)
    for t in range(B * S):
        for k in range(K):
            if keep[t, k]:
                out[t] += weights[t, k] * y_all[idx[t, k], t]
    return out.reshape(B, S, D), idx, int((~keep).sum())


@pytest.fixture(scope="module")
def setup():
    p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) \
        .astype(jnp.bfloat16)
    mesh = compat.make_mesh((1,), ("model",))
    return p, x, mesh


def _run_a2a(p, x, mesh, cf):
    rules = ShardingRules(mesh=mesh, dp=(), ep=("model",), fsdp=None,
                          moe_dispatch="a2a", capacity_factor=cf,
                          moe_impl="capacity")
    with compat.use_mesh(mesh):
        y, tally, _ = jax.jit(lambda p, x: MOE.moe_layer(
            p, x, top_k=K, n_experts=E, rules=rules, phase="train"))(p, x)
    return np.asarray(y, np.float32), np.asarray(tally)


def test_a2a_drop_path_conserves_output(setup):
    """With a starved capacity, the a2a output equals the dense oracle with
    the overflowing assignments zeroed — dropped assignments contribute
    nothing, kept ones keep their unmodified gate weights."""
    p, x, mesh = setup
    cf = 0.25
    capacity = MOE._round_up(max(int(np.ceil(B * S * K / E * cf)), 1), 4)
    y, tally = _run_a2a(p, x, mesh, cf)
    ref, idx, n_dropped = _reference_with_drops(p, x, capacity)
    assert n_dropped > 0, "fixture failed to overflow any bucket"
    np.testing.assert_allclose(y, ref, atol=5e-2, rtol=5e-2)
    # drop column matches the replayed bucket accounting exactly
    assert tally[-1] == n_dropped
    # logical tallies are pre-capacity routing counts: conserved regardless
    np.testing.assert_allclose(tally[:E],
                               np.bincount(idx.ravel(), minlength=E))
    assert tally[:E].sum() == B * S * K


def test_a2a_no_drops_at_generous_capacity(setup):
    p, x, mesh = setup
    y, tally = _run_a2a(p, x, mesh, cf=8.0)
    assert tally[-1] == 0
    y_ref, tally_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E,
                                        rules=None)
    np.testing.assert_allclose(y, np.asarray(y_ref, np.float32),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(tally, np.asarray(tally_ref))


def test_dense_path_never_drops(setup):
    p, x, _ = setup
    _, tally, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E, rules=None)
    assert tally.shape == (E + 1,)
    assert tally[-1] == 0


def test_replicated_path_surfaces_drops(setup):
    """The decode (replicated) body counts its local bucket overflow too:
    a router biased onto one expert overflows that expert's bucket."""
    p, x, mesh = setup
    p_hot = dict(p)
    bias = np.zeros((D, E), np.float32)
    bias[:, 0] = 3.0                       # softmax mass piles on expert 0
    p_hot["router"] = p["router"] + jnp.asarray(bias)
    x_pos = jnp.abs(x)                     # positive inputs → bias dominates
    rules = ShardingRules(mesh=mesh, dp=(), ep=("model",),
                          ep_all=("model",), fsdp=None,
                          moe_dispatch="replicated", capacity_factor=2.0,
                          moe_impl="capacity")
    with compat.use_mesh(mesh):
        y, tally, _ = jax.jit(lambda p, x: MOE.moe_layer(
            p, x, top_k=1, n_experts=E, rules=rules, phase="decode"))(
            p_hot, x_pos)
    tally = np.asarray(tally)
    assert tally[:E].sum() == B * S            # top-1: one draw per token
    assert tally[-1] > 0, "hot expert failed to overflow its bucket"
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_engine_accumulates_dropped_assignments():
    """EngineStats surfaces the per-step drop column (0 on the dense smoke
    path, but the accounting plumbing must run end-to-end)."""
    from repro.configs import get_smoke
    from repro.serving import Engine, WORKLOADS, sample_requests

    eng = Engine(get_smoke("qwen3-moe-235b-a22b"), max_batch=2, max_seq=48)
    reqs = sample_requests(WORKLOADS["sharegpt"], 2, qps=100.0, seed=0)
    reqs = [type(r)(r.req_id, r.arrival, 8, 4) for r in reqs]
    eng.submit(reqs)
    eng.run(max_steps=60)
    assert eng.stats.steps > 0
    assert eng.stats.dropped_assignments == 0.0     # dense path: no drops
