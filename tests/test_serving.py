"""Serving layer: workloads, metrics, simulator behavior, engine E2E."""

import numpy as np
import pytest

from repro.configs import get, get_smoke
from repro.core import (DriftConfig, ViBEConfig, ViBEController,
                        make_cluster, solve_model_placement)
from repro.serving import (Engine, EPSimulator, PAPER_SLOS, SLO, SimConfig,
                           WORKLOADS, goodput, routing_profile,
                           sample_requests, slo_frontier, step_loads,
                           summarize)
from repro.serving.simulator import rank_latency_matrix


class TestWorkload:
    def test_poisson_arrivals_rate(self):
        reqs = sample_requests(WORKLOADS["sonnet"], 2000, qps=10.0, seed=0)
        duration = reqs[-1].arrival
        assert 2000 / duration == pytest.approx(10.0, rel=0.15)

    def test_sonnet_fixed_lengths(self):
        reqs = sample_requests(WORKLOADS["sonnet"], 50, qps=1.0)
        assert all(r.prompt_len == 1024 and r.output_len == 128
                   for r in reqs)

    def test_sharegpt_variable_lengths(self):
        reqs = sample_requests(WORKLOADS["sharegpt"], 3000, qps=1.0, seed=1)
        p = np.array([r.prompt_len for r in reqs])
        assert p.mean() == pytest.approx(219.2, rel=0.2)
        assert p.std() > 50

    def test_routing_profile_stable_and_skewed(self):
        prof = routing_profile(WORKLOADS["sonnet"], 8, 64)
        np.testing.assert_allclose(prof.sum(1), 1.0, rtol=1e-9)
        # Dirichlet(0.3) produces hot experts (paper Fig 4 skew)
        assert prof.max(axis=1).mean() > 3.0 / 64

    def test_step_loads_sum(self):
        rng = np.random.default_rng(0)
        prof = routing_profile(WORKLOADS["sonnet"], 4, 16)
        loads = step_loads(prof, tokens=100, top_k=4, rng=rng)
        np.testing.assert_array_equal(loads.sum(1), 400)


class TestMetrics:
    def test_goodput_and_frontier(self):
        from repro.serving.metrics import RequestRecord
        recs = []
        for i in range(10):
            r = RequestRecord(i, 0.0, 10, 5)
            r.first_token_at = 0.1 if i < 9 else 0.9
            r.finished_at = r.first_token_at + 4 * 0.01
            recs.append(r)
        slo = SLO(ttft=0.5, tpot=0.02)
        assert goodput(recs, slo) == pytest.approx(0.9)
        f = slo_frontier({1.0: 1.0, 2.0: 0.95, 3.0: 0.5}, target=0.9)
        assert 2.0 < f < 3.0

    def test_frontier_non_monotone_dip(self):
        """Goodput dips below target between non-adjacent above-target
        samples: the frontier is the interpolated crossing *into* the dip,
        not the (noisy) recovery point further out."""
        curve = {1.0: 1.0, 2.0: 0.95, 3.0: 0.5, 4.0: 0.95, 5.0: 0.2}
        f = slo_frontier(curve, target=0.9)
        assert f == pytest.approx(2.0 + (0.95 - 0.9) / (0.95 - 0.5))
        assert f < 3.0                       # never sails past the dip

    def test_frontier_edge_cases(self):
        assert slo_frontier({}, target=0.9) == 0.0
        # already failing at the lowest sampled rate → nothing sustainable
        assert slo_frontier({1.0: 0.5, 2.0: 0.95}, target=0.9) == 0.0
        # never dips → the largest sampled rate (no extrapolation)
        assert slo_frontier({1.0: 0.99, 2.0: 0.92}, target=0.9) == 2.0
        # a sample sitting exactly at target still counts as sustained;
        # the crossing then starts from it
        assert slo_frontier({1.0: 0.95, 2.0: 0.9, 3.0: 0.1},
                            target=0.9) == pytest.approx(2.0)


class TestSimulator:
    def setup_method(self):
        self.model = get("deepseek-v3-671b")
        self.wl = WORKLOADS["sonnet"]
        self.cluster = make_cluster(
            8, "mi325x", d_model=self.model.d_model,
            d_ff=self.model.moe_d_ff,
            experts_per_rank=self.model.n_experts // 8)
        self.perf = self.cluster.fit_models()
        L, E = self.model._n_moe_layers(), self.model.n_experts
        self.W = routing_profile(self.wl, L, E) * 16384 * self.model.top_k

    def _run(self, policy, qps=20.0, n=120, **kw):
        pl = solve_model_placement(
            policy, self.W, 8,
            perf_models=self.perf if policy == "vibe" else None)
        sim = EPSimulator(self.model, self.cluster, self.wl,
                          SimConfig(ep_degree=8, seed=1,
                                    max_prefill_tokens=16384, **kw),
                          placement=pl)
        recs = sim.run(sample_requests(self.wl, n, qps=qps, seed=2),
                       phase="prefill")
        return sim, recs

    def test_policy_ordering_at_saturation(self):
        """Paper Fig 8a: vLLM < EPLB ≤ ViBE goodput on sonnet."""
        slo = PAPER_SLOS[("sonnet", "deepseek-v3-671b")]
        gps = {}
        for policy in ("contiguous", "eplb", "vibe"):
            _, recs = self._run(policy, qps=22.0)
            gps[policy] = goodput(recs, slo)
        assert gps["contiguous"] <= gps["eplb"] + 0.02
        assert gps["eplb"] <= gps["vibe"] + 0.02

    def test_layer_latency_ordering(self):
        """Layer-level max and gap: contiguous > eplb ≥ vibe (Fig 10a)."""
        res = {}
        for policy in ("contiguous", "eplb", "vibe"):
            pl = solve_model_placement(
                policy, self.W, 8,
                perf_models=self.perf if policy == "vibe" else None)
            rt = rank_latency_matrix(self.cluster, pl.rank_loads(self.W))
            res[policy] = (rt.max(1).mean(), (rt.max(1) - rt.min(1)).mean())
        assert res["contiguous"][0] > res["eplb"][0] * 1.1
        assert res["vibe"][1] <= res["eplb"][1] * 1.05
        assert res["vibe"][0] <= res["eplb"][0] * 1.005

    def test_barrier_idle_accounting(self):
        sim, _ = self._run("contiguous", n=40)
        assert sim.total_barrier_idle > 0
        assert sim.steps > 0
        util = sim.utilization_spread()
        assert util.sum() == pytest.approx(1.0)

    def test_adaptive_recalibration_under_drift(self):
        """§5.4: profile on sonnet, serve sharegpt → adaptive recovers."""
        L, E = self.model._n_moe_layers(), self.model.n_experts
        ctl = ViBEController(
            L, E, 8, self.perf,
            ViBEConfig(policy="vibe", adaptive=True,
                       drift=DriftConfig(window=20, interval=5, cooldown=10),
                       expert_bytes=3 * self.model.d_model
                       * self.model.moe_d_ff * 2),
            initial_w=self.W)
        sim = EPSimulator(self.model, self.cluster, self.wl,
                          SimConfig(ep_degree=8, seed=3,
                                    max_prefill_tokens=16384),
                          controller=ctl)
        drift_prof = routing_profile(WORKLOADS["sharegpt"], L, E)
        reqs = sample_requests(self.wl, 150, qps=20.0, seed=4)
        sim.run(reqs, phase="prefill", drift_profile=drift_prof, drift_at=1.0)
        assert ctl.updates, "no recalibration fired under workload switch"
        assert sim.migration_stalls, "migration stall not accounted"


class TestEngine:
    def _engine(self, policy="vibe", adaptive=True, arch="qwen3-moe-235b-a22b"):
        cfg = get_smoke(arch)
        from repro.models import moe_perm_shape
        n_moe, n_slots = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                               d_ff=cfg.moe_d_ff,
                               experts_per_rank=n_slots // 4)
        ctl = ViBEController(
            n_moe, n_slots, 4, cluster.fit_models(),
            ViBEConfig(policy=policy, adaptive=adaptive,
                       drift=DriftConfig(window=8, interval=4, cooldown=4),
                       expert_bytes=3 * cfg.d_model * cfg.moe_d_ff * 2))
        return Engine(cfg, controller=ctl, cluster=cluster,
                      max_batch=2, max_seq=48, seed=0)

    def test_engine_serves_requests_end_to_end(self):
        eng = self._engine()
        reqs = sample_requests(WORKLOADS["sharegpt"], 4, qps=100.0, seed=0)
        reqs = [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]
        eng.submit(reqs)
        records = eng.run(max_steps=200)
        done = [r for r in records if np.isfinite(r.finished_at)]
        assert len(done) == 4
        s = summarize(records)
        assert s["ttft_p50"] > 0
        assert eng.stats.decode_steps > 0

    def test_engine_placement_migration_preserves_outputs(self):
        """Recalibration must not change model semantics: greedy decode of
        a fixed prompt is identical before/after a forced migration."""
        import jax.numpy as jnp
        eng = self._engine()
        prompt = jnp.arange(8, dtype=jnp.int32)[None, :] % eng.cfg.vocab
        lg0, _, _ = eng._prefill(eng.params, {"tokens": prompt},
                                 eng.moe_tables)
        # force a non-trivial permutation through the migration path
        rng = np.random.default_rng(0)
        perm = np.stack([rng.permutation(eng.n_slots)
                         for _ in range(eng.n_moe)]).astype(np.int32)
        moved = eng._apply_perm(perm)
        assert moved > 0
        lg1, _, _ = eng._prefill(eng.params, {"tokens": prompt},
                                 eng.moe_tables)
        np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                   atol=1e-2, rtol=1e-2)

    def test_engine_vibe_r_expanded_slots_end_to_end(self):
        """ViBE-R in the real engine: the slot budget grows beyond
        one-per-expert, the controller's replicated slot table is applied
        to the stacked weights, and serving still completes."""
        eng = self._engine(policy="vibe_r")
        assert eng.n_slots > eng.cfg.n_experts          # replica slots exist
        pl = eng.controller.placement
        assert pl.perm.shape == (eng.n_moe, eng.n_slots)
        assert pl.n_copies().max() >= 2                 # something replicated
        reqs = sample_requests(WORKLOADS["sharegpt"], 3, qps=100.0, seed=1)
        reqs = [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]
        eng.submit(reqs)
        records = eng.run(max_steps=200)
        done = [r for r in records if np.isfinite(r.finished_at)]
        assert len(done) == 3
        assert eng.stats.virtual_time > 0

    def test_engine_vibe_r_migration_preserves_outputs(self):
        """Replicated slot-table migration keeps greedy decode semantics:
        copies hold identical weights, so moving them is invisible."""
        import jax.numpy as jnp
        eng = self._engine(policy="vibe_r")
        prompt = jnp.arange(8, dtype=jnp.int32)[None, :] % eng.cfg.vocab
        lg0, _, _ = eng._prefill(eng.params, {"tokens": prompt},
                                 eng.moe_tables)
        # a different replicated placement (fresh skewed profile) → migrate
        rng = np.random.default_rng(2)
        E = eng.controller.E
        w = rng.dirichlet(np.full(E, 0.2), size=eng.n_moe) * 10_000
        from repro.core import vibe_r_placement
        rp = vibe_r_placement(w, eng.controller.perf_models,
                              slots_per_rank=eng.n_slots // 4)
        eng.controller.placement = rp
        moved = eng._apply_perm(eng._controller_perm())
        assert moved > 0
        lg1, _, _ = eng._prefill(eng.params, {"tokens": prompt},
                                 eng.moe_tables)
        np.testing.assert_allclose(np.asarray(lg0), np.asarray(lg1),
                                   atol=1e-2, rtol=1e-2)
