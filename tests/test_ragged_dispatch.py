"""Ragged (dropless) MoE dispatch coverage (ISSUE 4 acceptance gates).

Four layers of contract, mirroring the capacity suite's structure:

* **kernel** — ``ragged_moe_ffn_pallas`` (interpret mode) against the
  pure-jnp ``ragged_moe_ffn_ref`` oracle and against per-expert
  ``moe_ffn_ref`` rows; tile metadata invariants; empty experts own no
  tiles and unoccupied tiles emit zeros.
* **plan** — the sort-based ``_bucket_positions`` is bit-identical to the
  historical one-hot/cumsum build (stable sort == arrival order), active
  mask included.
* **dispatch** — property tests: the ragged path equals the dense oracle
  for *any* routing (no drop column — ``tally[E] == 0`` structurally),
  and equals the capacity path wherever capacity does not drop; where
  capacity *does* drop, ragged still equals the full oracle.
* **bodies** — the real ``shard_map`` a2a/replicated ragged bodies run
  in-process on a 1-device mesh (fast-lane coverage like
  ``test_capacity_overflow``), gradients included.

Plus the vectorized weight-migration builds (``placement_gather_indices``,
``expand_experts``) pinned bit-identical to their old pure-Python loops.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import compat
from repro.kernels.ragged_moe_ffn import (ragged_moe_ffn_pallas,
                                          ragged_n_tiles,
                                          ragged_tile_metadata)
from repro.kernels.ref import moe_ffn_ref, ragged_moe_ffn_ref
from repro.models import moe as MOE
from repro.models.sharding import ShardingRules

E, D, F, K = 4, 16, 64, 2
B, S = 2, 16


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

def _ragged_buffer(rng, sizes, bm, D, dtype=np.float32):
    """Zero-padded group-sorted buffer + metadata for given segment sizes."""
    sizes = np.asarray(sizes, np.int32)
    A = int(sizes.sum())
    nt = ragged_n_tiles(A, len(sizes), bm)
    row_off, tg = ragged_tile_metadata(jnp.asarray(sizes), bm, nt)
    off = np.asarray(row_off)
    buf = np.zeros((nt * bm, D), dtype)
    for g, s in enumerate(sizes):
        buf[off[g]:off[g] + s] = rng.standard_normal((s, D)).astype(dtype)
    return jnp.asarray(buf), tg, off


@pytest.mark.parametrize("sizes,bm", [
    ((5, 0, 17, 3), 8),        # empty expert in the middle
    ((0, 0, 0, 40), 16),       # all load on one expert
    ((1, 1, 1, 1), 8),         # minimum occupancy
    ((32, 32, 32, 32), 32),    # exactly tile-aligned
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_kernel_matches_ref(sizes, bm, dtype):
    rng = np.random.default_rng(sum(sizes) + bm)
    buf, tg, _ = _ragged_buffer(rng, sizes, bm, D,
                                np.float32 if dtype == jnp.float32
                                else np.float32)
    buf = buf.astype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    w1 = (jax.random.normal(ks[0], (E, D, F)) / np.sqrt(D)).astype(dtype)
    w3 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)).astype(dtype)
    w2 = (jax.random.normal(ks[2], (E, F, D)) / np.sqrt(F)).astype(dtype)
    y_ref = np.asarray(ragged_moe_ffn_ref(w1, w3, w2, buf, tg), np.float32)
    y_k = np.asarray(ragged_moe_ffn_pallas(w1, w3, w2, buf, tg, bf=32,
                                           interpret=True), np.float32)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(y_k, y_ref, atol=tol, rtol=tol)


def test_ragged_ref_matches_dense_oracle_per_expert():
    """Each occupied segment equals the capacity oracle run on its rows."""
    rng = np.random.default_rng(3)
    sizes = (7, 0, 12, 2)
    bm = 8
    buf, tg, off = _ragged_buffer(rng, sizes, bm, D)
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    w1 = (jax.random.normal(ks[0], (E, D, F)) / np.sqrt(D))
    w3 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D))
    w2 = (jax.random.normal(ks[2], (E, F, D)) / np.sqrt(F))
    y = np.asarray(ragged_moe_ffn_ref(w1, w3, w2, buf, tg))
    for g, s in enumerate(sizes):
        if s == 0:
            continue
        rows = jnp.asarray(np.asarray(buf)[off[g]:off[g] + s])
        y_d = np.asarray(moe_ffn_ref(w1[g:g + 1], w3[g:g + 1], w2[g:g + 1],
                                     rows[None]))[0]
        np.testing.assert_allclose(y[off[g]:off[g] + s], y_d,
                                   rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_tile_metadata_invariants(seed):
    rng = np.random.default_rng(seed)
    G = int(rng.integers(1, 9))
    bm = int(2 ** rng.integers(0, 6))
    sizes = rng.integers(0, 40, size=G).astype(np.int32)
    A = int(sizes.sum())
    nt = ragged_n_tiles(A, G, bm)
    row_off, tg = ragged_tile_metadata(jnp.asarray(sizes), bm, nt)
    row_off, tg = np.asarray(row_off), np.asarray(tg)
    # segment starts are tile-aligned; total occupied rows bounded by n_rows
    assert (row_off % bm == 0).all()
    assert row_off[-1] <= nt * bm
    # each group owns exactly ceil(size/bm) tiles, contiguous and in order
    want_tiles = -(-sizes // bm)
    counts = np.bincount(tg[tg < G], minlength=G)
    np.testing.assert_array_equal(counts, want_tiles)
    assert (np.diff(tg) >= 0).all()                  # grouped + sorted
    # everything past the occupied prefix is sentinel
    assert (tg[int(want_tiles.sum()):] == G).all()


def test_ragged_kernel_unoccupied_tiles_zero():
    rng = np.random.default_rng(0)
    buf, tg, off = _ragged_buffer(rng, (3, 0, 5, 0), 8, D)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    w1 = (jax.random.normal(ks[0], (E, D, F)) / np.sqrt(D))
    w3 = (jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D))
    w2 = (jax.random.normal(ks[2], (E, F, D)) / np.sqrt(F))
    y = np.asarray(ragged_moe_ffn_pallas(w1, w3, w2, buf, tg, bf=32,
                                         interpret=True))
    occupied = np.zeros(y.shape[0], bool)
    for g, s in zip(range(E), (3, 0, 5, 0)):
        occupied[off[g]:off[g] + s] = True
    assert np.abs(y[~occupied]).max() == 0.0
    assert np.abs(y[occupied]).max() > 0.0


# ---------------------------------------------------------------------------
# plan level: sort-based bucketing == historical one-hot/cumsum
# ---------------------------------------------------------------------------

def _bucket_positions_onehot(slot_flat, n_slots, active=None):
    """The pre-ISSUE-4 O(A × n_slots) build, kept as the reference."""
    oh = jax.nn.one_hot(jnp.asarray(slot_flat), n_slots, dtype=jnp.int32)
    if active is not None:
        oh = oh * jnp.asarray(active).astype(jnp.int32)[:, None]
    pos = jnp.cumsum(oh, axis=0) - 1
    return jnp.take_along_axis(pos, jnp.asarray(slot_flat)[:, None],
                               axis=1)[:, 0]


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_sorted_bucket_positions_bit_identical(seed):
    rng = np.random.default_rng(seed)
    n_slots = int(rng.integers(1, 12))
    A = int(rng.integers(1, 200))
    slot = rng.integers(0, n_slots, size=A).astype(np.int32)
    active = rng.random(A) < 0.7
    new = np.asarray(MOE._bucket_positions(jnp.asarray(slot), n_slots))
    old = np.asarray(_bucket_positions_onehot(slot, n_slots))
    np.testing.assert_array_equal(new, old)
    # with a mask, only active positions are defined (callers mask the rest)
    new_m = np.asarray(MOE._bucket_positions(jnp.asarray(slot), n_slots,
                                             jnp.asarray(active)))
    old_m = np.asarray(_bucket_positions_onehot(slot, n_slots, active))
    np.testing.assert_array_equal(new_m[active], old_m[active])


# ---------------------------------------------------------------------------
# dispatch level
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    p = MOE.moe_init(jax.random.PRNGKey(0), d=D, f=F, n_experts=E, n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D)) \
        .astype(jnp.bfloat16)
    mesh = compat.make_mesh((1,), ("model",))
    return p, x, mesh


def _run(p, x, mesh, *, dispatch, impl, cf, phase, top_k=K, bm=8):
    rules = ShardingRules(mesh=mesh, dp=(), ep=("model",), ep_all=("model",),
                          fsdp=None, moe_dispatch=dispatch,
                          capacity_factor=cf, moe_impl=impl, moe_block_m=bm)
    with compat.use_mesh(mesh):
        y, tally, _ = jax.jit(lambda p, x: MOE.moe_layer(
            p, x, top_k=top_k, n_experts=E, rules=rules, phase=phase))(p, x)
    return np.asarray(y, np.float32), np.asarray(tally)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_ragged_dense_equals_oracle(seed):
    """Ragged == dense oracle for any routing, with a structurally zero
    drop column — the dropless contract (no mesh needed: the ragged dense
    dispatch runs whenever rules carry moe_impl='ragged')."""
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, 40))
    top_k = int(rng.integers(1, E + 1))
    p = MOE.moe_init(jax.random.PRNGKey(seed), d=D, f=F, n_experts=E,
                     n_slots=E)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, D),
                          jnp.float32)
    y_ref, t_ref, a_ref = MOE.moe_layer(p, x, top_k=top_k, n_experts=E,
                                        rules=None)
    rules = ShardingRules(mesh=None, moe_impl="ragged", moe_block_m=8)
    y, tally, aux = MOE.moe_layer(p, x, top_k=top_k, n_experts=E,
                                  rules=rules)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(tally), np.asarray(t_ref))
    assert float(tally[E]) == 0.0
    np.testing.assert_allclose(float(aux), float(a_ref), rtol=1e-6)


def test_ragged_equals_capacity_when_no_drops(setup):
    """Wherever the capacity path does not drop, both implementations are
    the same function (modulo summation order ≤ 1 bf16 ULP)."""
    p, x, mesh = setup
    for dispatch, phase in (("a2a", "train"), ("replicated", "decode")):
        y_c, t_c = _run(p, x, mesh, dispatch=dispatch, impl="capacity",
                        cf=8.0, phase=phase)
        y_r, t_r = _run(p, x, mesh, dispatch=dispatch, impl="ragged",
                        cf=8.0, phase=phase)
        assert t_c[-1] == 0, "fixture unexpectedly dropped"
        np.testing.assert_array_equal(t_c, t_r)
        np.testing.assert_allclose(y_r, y_c, atol=1e-3, rtol=1e-3)


def test_ragged_dropless_where_capacity_drops(setup):
    """At a starved capacity factor the capacity path drops; the ragged
    path keeps every assignment and still equals the full dense oracle."""
    p, x, mesh = setup
    y_ref, t_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E, rules=None)
    y_c, t_c = _run(p, x, mesh, dispatch="a2a", impl="capacity", cf=0.25,
                    phase="train")
    assert t_c[-1] > 0, "fixture failed to overflow any bucket"
    y_r, t_r = _run(p, x, mesh, dispatch="a2a", impl="ragged", cf=0.25,
                    phase="train")
    assert t_r[-1] == 0
    np.testing.assert_allclose(y_r, np.asarray(y_ref, np.float32),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_array_equal(t_r[:E], np.asarray(t_ref)[:E])
    # same on the decode path (replicated body, local buckets)
    y_rr, t_rr = _run(p, x, mesh, dispatch="replicated", impl="ragged",
                      cf=0.25, phase="decode")
    assert t_rr[-1] == 0
    np.testing.assert_allclose(y_rr, np.asarray(y_ref, np.float32),
                               atol=1e-3, rtol=1e-3)


def test_ragged_gradients_flow(setup):
    """The sort/scatter/gather pipeline is differentiable end to end."""
    p, x, mesh = setup
    rules = ShardingRules(mesh=mesh, dp=(), ep=("model",), fsdp=None,
                          moe_dispatch="a2a", moe_impl="ragged",
                          moe_block_m=8)

    def loss(p, x):
        y, _, a = MOE.moe_layer(p, x, top_k=K, n_experts=E, rules=rules,
                                phase="train")
        return (y.astype(jnp.float32) ** 2).mean() + 0.01 * a

    with compat.use_mesh(mesh):
        g = jax.jit(jax.grad(loss))(p, x)
    for k, v in g.items():
        assert float(jnp.linalg.norm(v.astype(jnp.float32))) > 0, k


def test_ragged_weighted_replica_routing(setup):
    """copy_cdf share-weighted replica selection rides the ragged path:
    replicated slots + skewed shares still reproduce the dense oracle."""
    p, x, mesh = setup
    from repro.models.sharding import build_copy_cdf, build_slots_of
    ns = E + 2
    perm = np.concatenate([np.arange(E), [0, 1]])[None, :].astype(np.int32)
    p_rep = {k: (v if k == "router" else v[perm[0]]) for k, v in p.items()}
    share = np.ones((1, ns))
    share[0, :2] = 0.3
    share[0, E:] = 0.7
    so, nc = build_slots_of(perm, E, ns)
    cdf = build_copy_cdf(perm, E, ns, share=share)
    y_ref, t_ref, _ = MOE.moe_layer(p, x, top_k=K, n_experts=E, rules=None)
    rules = ShardingRules(mesh=mesh, dp=(), ep=("model",), fsdp=None,
                          moe_dispatch="a2a", moe_impl="ragged",
                          moe_block_m=8)
    with compat.use_mesh(mesh):
        y, tally, _ = jax.jit(lambda pp, xx: MOE.moe_layer(
            pp, xx, top_k=K, n_experts=E, rules=rules,
            slots_of=jnp.asarray(so[0]), n_copies=jnp.asarray(nc[0]),
            copy_cdf=jnp.asarray(cdf[0]), phase="train"))(p_rep, x)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_array_equal(np.asarray(tally), np.asarray(t_ref))


# ---------------------------------------------------------------------------
# vectorized weight-migration builds == historical Python loops
# ---------------------------------------------------------------------------

def _gather_indices_loop(old_perm, new_perm):
    """Pre-ISSUE-4 pure-Python build, kept as the bit-identity reference."""
    old_perm = np.atleast_2d(old_perm)
    new_perm = np.atleast_2d(new_perm)
    L, NS = old_perm.shape
    idx = np.empty((L, NS), dtype=np.int32)
    for l in range(L):
        inv = np.full(max(int(old_perm.max()), int(new_perm.max())) + 1, -1,
                      dtype=np.int32)
        for q in range(NS):
            if inv[old_perm[l, q]] < 0:
                inv[old_perm[l, q]] = q
        for pslot in range(NS):
            src = inv[new_perm[l, pslot]]
            idx[l, pslot] = src if src >= 0 else pslot
    return idx


def _expand_gi_loop(perm_a2a, perm_dec):
    L, ns_dec = np.atleast_2d(perm_dec).shape
    perm_a2a = np.atleast_2d(perm_a2a)
    perm_dec = np.atleast_2d(perm_dec)
    gi = np.empty((L, ns_dec), dtype=np.int32)
    for l in range(L):
        inv = {int(e): q for q, e in reversed(list(enumerate(perm_a2a[l])))}
        for pslot in range(ns_dec):
            gi[l, pslot] = inv[int(perm_dec[l, pslot])]
    return gi


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_gather_indices_bit_identical(seed):
    """Vectorized placement_gather_indices == the old per-slot scan, on
    permutations with replicas (repeated ids) and phantom padding."""
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 4))
    n_exp = int(rng.integers(2, 10))
    NS = int(rng.integers(n_exp, n_exp + 6))
    def perm():
        base = np.arange(n_exp, dtype=np.int32)
        extra = rng.integers(0, n_exp + 2, size=NS - n_exp).astype(np.int32)
        rows = [rng.permutation(np.concatenate([base, extra]))
                for _ in range(L)]
        return np.stack(rows)
    old, new = perm(), perm()
    np.testing.assert_array_equal(
        MOE.placement_gather_indices(old, new),
        _gather_indices_loop(old, new))


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_expand_experts_bit_identical(seed):
    rng = np.random.default_rng(seed)
    L = int(rng.integers(1, 4))
    n_exp = int(rng.integers(2, 8))
    ns_a2a = n_exp + int(rng.integers(0, 4))
    ns_dec = int(rng.integers(1, 3)) * ns_a2a
    perm_a2a = np.stack([
        rng.permutation(np.concatenate(
            [np.arange(n_exp), rng.integers(0, n_exp, size=ns_a2a - n_exp)]
        ).astype(np.int32)) for _ in range(L)])
    perm_dec = rng.integers(0, n_exp, size=(L, ns_dec)).astype(np.int32)
    w = {k: jnp.asarray(rng.standard_normal((L, ns_a2a, 2, 3)),
                        jnp.float32) for k in ("w1", "w2", "w3")}
    got = MOE.expand_experts(w, perm_a2a, perm_dec)
    gi = _expand_gi_loop(perm_a2a, perm_dec)
    for k in ("w1", "w2", "w3"):
        want = np.take_along_axis(np.asarray(w[k]), gi[:, :, None, None],
                                  axis=1)
        np.testing.assert_array_equal(np.asarray(got[k]), want)


def test_expand_experts_missing_expert_raises():
    w = {"w1": jnp.zeros((1, 2, 2, 2))}
    with pytest.raises(KeyError):
        MOE.expand_experts(w, np.array([[0, 1]]), np.array([[0, 3]]))
