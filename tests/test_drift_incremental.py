"""Drift detection (Alg 1 Phase 3) + incremental solver (Alg 2)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DriftConfig, DriftDetector, ViBEConfig,
                        ViBEController, cosine_distance, eplb_placement,
                        incremental_update, make_cluster, vibe_placement)


def _loads(rng, L, E, alpha=0.3, tokens=4096):
    prof = rng.dirichlet(np.full(E, alpha), size=L)
    return prof * tokens


class TestDrift:
    def test_no_trigger_on_steady_workload(self):
        rng = np.random.default_rng(0)
        det = DriftDetector(4, 16, DriftConfig(window=20, interval=5))
        base = _loads(rng, 4, 16)
        for _ in range(200):
            ev = det.observe(base * rng.uniform(0.95, 1.05), 4096)
            assert ev is None

    def test_routing_drift_triggers(self):
        rng = np.random.default_rng(1)
        det = DriftDetector(4, 16, DriftConfig(window=20, interval=5))
        base = _loads(rng, 4, 16)
        shifted = np.roll(base, 5, axis=1)           # different hot experts
        for _ in range(40):
            det.observe(base, 4096)
        events = [det.observe(shifted, 4096) for _ in range(40)]
        fired = [e for e in events if e is not None]
        assert fired and fired[0].kind == "routing"
        assert fired[0].max_cos_distance > 0.05

    def test_magnitude_drift_triggers_stress_event(self):
        """Same routing ratios, 4× the tokens — EPLB can't see this; ViBE's
        magnitude monitor must (paper §4.2.4)."""
        rng = np.random.default_rng(2)
        det = DriftDetector(4, 16, DriftConfig(window=20, interval=5,
                                               delta_mag=0.5))
        base = _loads(rng, 4, 16)
        for _ in range(40):
            det.observe(base, 4096)
        fired = [det.observe(base * 4, 4 * 4096) for _ in range(40)]
        fired = [e for e in fired if e is not None]
        assert fired and fired[0].kind == "stress"

    def test_cooldown_suppresses_retrigger(self):
        rng = np.random.default_rng(3)
        cfg = DriftConfig(window=10, interval=2, cooldown=30)
        det = DriftDetector(2, 8, cfg)
        base = _loads(rng, 2, 8)
        for _ in range(20):
            det.observe(base, 1000)
        det.snapshot()
        shifted = np.roll(base, 3, axis=1)
        fired = [det.observe(shifted, 1000) for _ in range(29)]
        assert all(e is None for e in fired)         # inside cooldown

    def test_cosine_distance_edge_cases(self):
        assert cosine_distance(np.zeros(4), np.zeros(4)) == 0.0
        assert cosine_distance(np.zeros(4), np.ones(4)) == 1.0
        assert cosine_distance(np.ones(4), np.ones(4)) == pytest.approx(0.0)


class TestIncremental:
    def setup_method(self):
        self.cluster = make_cluster(8, "mi325x", d_model=1024, d_ff=512,
                                    experts_per_rank=8)
        self.perf = self.cluster.fit_models()
        rng = np.random.default_rng(4)
        self.w0 = _loads(rng, 6, 64, tokens=40_000)
        self.w1 = np.roll(self.w0, 7, axis=1)

    def test_converges_and_moves_few_experts(self):
        pl = vibe_placement(self.w0, self.perf)
        res = incremental_update(pl, self.w1, self.perf, epsilon=0.03)
        full = vibe_placement(self.w1, self.perf)
        # paper: 5–30 swaps/layer vs >200 slot reassignments for a re-solve
        assert res.per_layer_swaps.max() <= 64
        assert res.moved_expert_count() < full.moved_experts(pl)
        assert res.converged_layers >= 4

    def test_update_improves_max_latency(self):
        """Alg 2 stops at tolerance OR when no swap helps; either way the
        updated placement is no worse and usually strictly better."""
        from repro.core import predicted_layer_latency
        pl = vibe_placement(self.w0, self.perf)
        res = incremental_update(pl, self.w1, self.perf, epsilon=0.05)
        better = 0
        for l in range(6):
            before = predicted_layer_latency(pl.assign[l], self.w1[l],
                                             self.perf).max()
            after = predicted_layer_latency(res.placement.assign[l],
                                            self.w1[l], self.perf).max()
            assert after <= before + 1e-12
            better += after < before - 1e-12
        assert better >= 3
        assert res.converged_layers >= 1

    def test_uniform_slots_preserved(self):
        pl = eplb_placement(self.w0, 8)
        res = incremental_update(pl, self.w1, self.perf)
        counts = np.apply_along_axis(np.bincount, 1, res.placement.assign,
                                     minlength=8)
        assert (counts == 8).all()

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 500))
    def test_property_never_increases_max_latency(self, seed):
        from repro.core import predicted_layer_latency
        rng = np.random.default_rng(seed)
        w0 = _loads(rng, 2, 32, tokens=30_000)
        w1 = _loads(rng, 2, 32, tokens=30_000)
        pl = eplb_placement(w0, 8)
        res = incremental_update(pl, w1, self.perf)
        for l in range(2):
            before = predicted_layer_latency(pl.assign[l], w1[l],
                                             self.perf).max()
            after = predicted_layer_latency(res.placement.assign[l], w1[l],
                                            self.perf).max()
            assert after <= before + 1e-12


class TestController:
    def test_end_to_end_recalibration(self):
        """Alg 1 over a drifting workload: trigger → incremental update →
        snapshot → cooldown."""
        cluster = make_cluster(4, "mi325x", d_model=256, d_ff=128,
                               experts_per_rank=4)
        perf = cluster.fit_models()
        rng = np.random.default_rng(5)
        w0 = _loads(rng, 3, 16, tokens=20_000)
        ctl = ViBEController(
            3, 16, 4, perf,
            ViBEConfig(policy="vibe", adaptive=True, expert_bytes=1000,
                       drift=DriftConfig(window=10, interval=5, cooldown=5)))
        for _ in range(30):
            upd = ctl.observe(w0 * rng.uniform(0.97, 1.03))
            assert upd is None
        w1 = np.roll(w0, 6, axis=1)
        updates = [ctl.observe(w1) for _ in range(40)]
        updates = [u for u in updates if u is not None]
        assert updates, "controller never recalibrated under drift"
        assert updates[0].moved_experts > 0
        assert updates[0].migration_bytes == updates[0].moved_experts * 1000

    def test_static_controller_never_updates(self):
        cluster = make_cluster(4, "mi325x", d_model=256, d_ff=128,
                               experts_per_rank=4)
        ctl = ViBEController(2, 8, 4, cluster.fit_models(),
                             ViBEConfig(policy="vibe", adaptive=False))
        rng = np.random.default_rng(6)
        for i in range(60):
            w = _loads(rng, 2, 8) * (1 + i)
            assert ctl.observe(w) is None
