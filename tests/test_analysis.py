"""repro.analysis: rule battery, suppressions, baseline, CLI, registry.

Each rule family gets a positive fixture (the rule must fire) and a
negative one (the rule must stay silent) — a linter that never fires and
a linter that cries wolf are equally useless, so both directions are
pinned. The final test runs the real analyzer over the real ``src/`` tree
and requires zero findings: the committed code *is* the negative fixture
for every rule at once.
"""

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (Baseline, Finding, UnknownRuleError, analyze,
                            get_rule, load_project, register_rule,
                            registered_rules)
from repro.analysis.cli import main as cli_main
from repro.analysis.rules.clock_parity import ClockParityRule

REPO = Path(__file__).resolve().parent.parent


def _write(root: Path, rel: str, body: str) -> Path:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(body))
    return p


def _rules(report, family=None):
    out = [f.rule for f in report.active]
    return [r for r in out if family is None or r.startswith(family + ".")]


# ---------------------------------------------------------------------------
# findings + registry
# ---------------------------------------------------------------------------

class TestFindingAndRegistry:
    def test_finding_validates_severity_and_rule_id(self):
        with pytest.raises(ValueError, match="severity"):
            Finding("a.py", 1, "det.x", "m", severity="fatal")
        with pytest.raises(ValueError, match="family.check"):
            Finding("a.py", 1, "nodot", "m")

    def test_render_github_workflow_command(self):
        f = Finding("src/a.py", 7, "det.wall-clock", "msg")
        assert f.render_github() == \
            "::error file=src/a.py,line=7,title=det.wall-clock::msg"
        w = Finding("src/a.py", 7, "trace.shape-branch", "msg",
                    severity="warning")
        assert w.render_github().startswith("::warning ")

    def test_builtin_families_registered(self):
        assert set(registered_rules()) >= {"trace", "det", "parity",
                                           "frozen", "imports"}

    def test_duplicate_family_rejected_unless_replace(self):
        class Dup:
            family = "det"
            scope = "file"

            def check(self, pf):
                return iter(())

        with pytest.raises(ValueError, match="already"):
            register_rule(Dup)
        orig = get_rule("det")
        try:
            register_rule(Dup, replace=True)
            assert isinstance(get_rule("det"), Dup)
        finally:
            register_rule(orig, replace=True)

    def test_unknown_family_names_registered_ones(self):
        with pytest.raises(UnknownRuleError, match="parity"):
            get_rule("nope")

    def test_non_conforming_rule_rejected(self):
        with pytest.raises(ValueError, match="family"):
            register_rule(object())


# ---------------------------------------------------------------------------
# suppressions + baseline + driver
# ---------------------------------------------------------------------------

class TestSuppressions:
    BAD = """\
        import time

        def f():
            return time.time()
    """

    def test_justified_suppression_silences_one_line(self, tmp_path):
        _write(tmp_path, "repro/core/x.py",
               self.BAD.replace(
                   "return time.time()",
                   "return time.time()  "
                   "# viblint: ignore[det.wall-clock] -- test fixture"))
        rep = analyze([tmp_path], root=tmp_path)
        assert _rules(rep, "det") == []
        assert [f.rule for f in rep.suppressed] == ["det.wall-clock"]
        assert rep.suppression_count == 1

    def test_family_prefix_suppresses_whole_family(self, tmp_path):
        _write(tmp_path, "repro/core/x.py",
               self.BAD.replace(
                   "return time.time()",
                   "return time.time()  # viblint: ignore[det] -- fixture"))
        rep = analyze([tmp_path], root=tmp_path)
        assert _rules(rep, "det") == []

    def test_unjustified_suppression_is_a_finding_and_inert(self, tmp_path):
        _write(tmp_path, "repro/core/x.py",
               self.BAD.replace(
                   "return time.time()",
                   "return time.time()  # viblint: ignore[det.wall-clock]"))
        rep = analyze([tmp_path], root=tmp_path)
        # the original finding survives AND the bare marker is flagged
        assert "det.wall-clock" in _rules(rep)
        assert "suppress.unjustified" in _rules(rep)
        assert rep.suppression_count == 0

    def test_malformed_marker_flagged(self, tmp_path):
        _write(tmp_path, "repro/core/x.py",
               "x = 1  # viblint ignore[det.wall-clock] -- typo no colon\n")
        rep = analyze([tmp_path], root=tmp_path)
        assert "suppress.malformed" in _rules(rep)

    def test_marker_in_docstring_is_inert(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", '''\
            """Docs may quote `# viblint: ignore[det]` without effect."""
            x = 1
        ''')
        rep = analyze([tmp_path], root=tmp_path)
        assert rep.active == []
        assert rep.suppression_count == 0

    def test_syntax_error_reported_not_crashed(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", "def f(:\n")
        rep = analyze([tmp_path], root=tmp_path)
        assert "parse.syntax-error" in _rules(rep)


class TestBaseline:
    def test_baselined_finding_grandfathers(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", TestSuppressions.BAD)
        rep = analyze([tmp_path], root=tmp_path)
        (key,) = [f.key() for f in rep.active if f.family == "det"]
        bl = Baseline(findings=[key])
        rep2 = analyze([tmp_path], root=tmp_path, baseline=bl)
        assert _rules(rep2, "det") == []
        assert [f.key() for f in rep2.baselined] == [key]

    def test_stale_baseline_entries_surfaced(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", "x = 1\n")
        bl = Baseline(findings=[("repro/core/x.py", "det.wall-clock",
                                 "long gone")])
        rep = analyze([tmp_path], root=tmp_path, baseline=bl)
        assert rep.ok
        assert len(rep.stale_baseline) == 1

    def test_dump_load_roundtrip(self, tmp_path):
        bl = Baseline(suppression_budget=3)
        f = Finding("a.py", 5, "det.wall-clock", "m")
        bl.dump(tmp_path / "b.json", [f])
        loaded = Baseline.load(tmp_path / "b.json")
        assert loaded.findings == [f.key()]
        assert loaded.suppression_budget == 3

    def test_select_and_ignore_filter_by_family(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", """\
            import time
            import os

            def f():
                return time.time()
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["imports"])
        assert _rules(rep) == ["imports.unused"]
        rep = analyze([tmp_path], root=tmp_path, ignore=["imports"])
        assert "imports.unused" not in _rules(rep)
        assert "det.wall-clock" in _rules(rep)


class TestCLI:
    def _fixture(self, tmp_path):
        _write(tmp_path, "repro/core/x.py", TestSuppressions.BAD)
        return tmp_path

    def test_exit_one_on_findings_zero_when_clean(self, tmp_path, capsys):
        root = self._fixture(tmp_path)
        assert cli_main([str(root), "--root", str(root)]) == 1
        assert "det.wall-clock" in capsys.readouterr().out
        _write(tmp_path, "repro/core/x.py", "x = 1\n")
        assert cli_main([str(root), "--root", str(root)]) == 0

    def test_github_format(self, tmp_path, capsys):
        root = self._fixture(tmp_path)
        cli_main([str(root), "--root", str(root), "--format", "github"])
        assert "::error file=" in capsys.readouterr().out

    def test_write_baseline_then_clean(self, tmp_path, capsys):
        root = self._fixture(tmp_path)
        bl = tmp_path / "bl.json"
        assert cli_main([str(root), "--root", str(root),
                         "--baseline", str(bl), "--write-baseline"]) == 0
        assert json.loads(bl.read_text())["findings"]
        assert cli_main([str(root), "--root", str(root),
                         "--baseline", str(bl)]) == 0


# ---------------------------------------------------------------------------
# rule battery: one positive + one negative fixture per family
# ---------------------------------------------------------------------------

class TestDeterminismRule:
    def test_fires_on_unseeded_wallclock_and_set_iteration(self, tmp_path):
        _write(tmp_path, "repro/core/bad.py", """\
            import time
            import random
            import numpy as np

            def f():
                t = time.time()
                x = np.random.rand(4)
                g = np.random.default_rng()
                r = random.random()
                out = []
                for v in {"a", "b"}:
                    out.append(v)
                return t, x, g, r, out
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["det"])
        rules = _rules(rep, "det")
        assert rules.count("det.unseeded-rng") == 3
        assert "det.wall-clock" in rules
        assert "det.set-iteration" in rules

    def test_silent_on_seeded_and_sorted(self, tmp_path):
        _write(tmp_path, "repro/core/good.py", """\
            import numpy as np

            def f(seed):
                rng = np.random.default_rng(seed)
                x = rng.normal(size=4)
                s = {"a", "b"}
                out = [v for v in sorted(s)]
                ok = "a" in s
                return x, out, ok
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["det"])
        assert _rules(rep, "det") == []

    def test_out_of_scope_dirs_exempt(self, tmp_path):
        _write(tmp_path, "repro/launch/bench.py", """\
            import time

            def f():
                return time.time()
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["det"])
        assert _rules(rep, "det") == []

    def test_set_bindings_scoped_per_function(self, tmp_path):
        # `dead` is a set in g() but a plain parameter in f(): iterating
        # the f() parameter must not inherit g()'s set binding
        _write(tmp_path, "repro/core/scoped.py", """\
            def f(dead):
                return tuple(sorted(set(int(x) for x in dead)))

            def g(self):
                dead = set([1, 2])
                return len(dead)
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["det"])
        assert _rules(rep, "det") == []


class TestFrozenConfigRule:
    def test_fires_outside_post_init_and_on_registry_mutation(self,
                                                              tmp_path):
        _write(tmp_path, "repro/core/bad.py", """\
            def get_policy(name):
                return name

            def tweak(cfg):
                object.__setattr__(cfg, "seed", 1)

            def hack():
                p = get_policy("vibe")
                p.solve = None
                get_policy("eplb").name = "x"
                setattr(get_policy("vibe"), "n", 2)
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["frozen"])
        rules = _rules(rep, "frozen")
        assert "frozen.setattr-outside-post-init" in rules
        assert rules.count("frozen.registry-mutation") == 3

    def test_silent_inside_post_init(self, tmp_path):
        _write(tmp_path, "repro/core/good.py", """\
            import dataclasses

            @dataclasses.dataclass(frozen=True)
            class C:
                x: int = 0

                def __post_init__(self):
                    object.__setattr__(self, "x", abs(self.x))
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["frozen"])
        assert _rules(rep, "frozen") == []


class TestUnusedImportRule:
    def test_fires_on_unused_silent_on_used(self, tmp_path):
        _write(tmp_path, "pkg/mod.py", """\
            import os
            import sys

            print(sys.argv)
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["imports"])
        (f,) = rep.active
        assert f.rule == "imports.unused"
        assert "'os'" in f.message

    def test_init_reexports_and_future_and_annotations_exempt(self,
                                                              tmp_path):
        _write(tmp_path, "pkg/__init__.py", "from .mod import thing\n")
        _write(tmp_path, "pkg/mod.py", """\
            from __future__ import annotations
            from typing import Optional

            thing: "Optional[int]" = None
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["imports"])
        assert rep.active == []


class TestTraceSafetyRule:
    def test_fires_on_branch_and_cast_in_jitted_fn(self, tmp_path):
        _write(tmp_path, "repro/kern.py", """\
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    x = x + 1
                n = int(x)
                return x.item() + n
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        rules = _rules(rep, "trace")
        assert "trace.python-branch" in rules
        assert rules.count("trace.concretize") == 2

    def test_taint_propagates_through_factory_and_callee(self, tmp_path):
        # the repo's dominant pattern: jax.jit(make_fn(cfg)) — the inner
        # closure is the traced function, and helpers it passes traced
        # values to inherit the hazard
        _write(tmp_path, "repro/fac.py", """\
            import jax

            def helper(v):
                if v > 0:
                    return v
                return -v

            def make_fn(cfg):
                def inner(x):
                    return helper(x) if cfg else x
                return inner

            step = jax.jit(make_fn(True))
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        assert "trace.python-branch" in _rules(rep, "trace")

    def test_static_argnames_params_stay_python(self, tmp_path):
        _write(tmp_path, "repro/kern.py", """\
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("bm", "flag"))
            def f(x, bm, flag):
                if bm > 8 and flag:
                    x = x * 2
                return x
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        assert _rules(rep, "trace") == []

    def test_config_only_helpers_and_untraced_code_silent(self, tmp_path):
        _write(tmp_path, "repro/app.py", """\
            import jax

            def pick(cfg):
                if cfg.is_moe:
                    return 1
                return 2

            def make_fn(cfg):
                mode = pick(cfg)

                def inner(x):
                    return x * mode
                return inner

            step = jax.jit(make_fn(object()))

            def host_side(x):
                if x > 0:
                    return int(x)
                return 0
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        assert _rules(rep, "trace") == []

    def test_shape_branch_is_a_warning(self, tmp_path):
        _write(tmp_path, "repro/kern.py", """\
            import jax

            @jax.jit
            def f(x):
                if x.shape[0] > 8:
                    return x * 2
                return x
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        (f,) = rep.active
        assert f.rule == "trace.shape-branch"
        assert f.severity == "warning"

    def test_string_key_membership_is_static(self, tmp_path):
        _write(tmp_path, "repro/kern.py", """\
            import jax

            @jax.jit
            def f(batch):
                if "patches" in batch:
                    return batch["patches"]
                return batch["tokens"]
        """)
        rep = analyze([tmp_path], root=tmp_path, select=["trace"])
        assert _rules(rep, "trace") == []


class TestClockParityRule:
    CFG = """\
        import dataclasses

        @dataclasses.dataclass(frozen=True)
        class FakeCfg:
            knob_a: float = 1.0
            knob_b: float = 2.0
            engine_only: float = 3.0

            def __post_init__(self):
                assert self.engine_only >= 0
    """
    ENG = "def price(cfg):\n    return cfg.knob_a + cfg.knob_b " \
          "+ cfg.engine_only\n"
    SIM = "def price(cfg):\n    return cfg.knob_a + cfg.knob_b\n"

    def _rule(self):
        return ClockParityRule(
            shared_configs=(("FakeCfg", "fake/cfg.py"),),
            engine_files=("fake/eng.py",), sim_files=("fake/sim.py",),
            shared_files=("fake/helper.py",))

    def _project(self, tmp_path, helper="x = 0\n"):
        _write(tmp_path, "fake/cfg.py", self.CFG)
        _write(tmp_path, "fake/eng.py", self.ENG)
        _write(tmp_path, "fake/sim.py", self.SIM)
        _write(tmp_path, "fake/helper.py", helper)
        project, _ = load_project([tmp_path], root=tmp_path)
        return project

    def test_catches_engine_only_knob(self, tmp_path):
        findings = list(self._rule().check(self._project(tmp_path)))
        (f,) = findings
        assert f.rule == "parity.one-clock"
        assert "FakeCfg.engine_only" in f.message
        assert "simulator" in f.message       # names the missing clock
        assert f.path == "fake/cfg.py"        # anchored at the declaration

    def test_shared_pricing_helper_counts_for_both_clocks(self, tmp_path):
        project = self._project(
            tmp_path, helper="def h(cfg):\n    return cfg.engine_only\n")
        assert list(self._rule().check(project)) == []

    def test_post_init_validation_is_not_pricing(self, tmp_path):
        # engine_only is read in __post_init__ (validation) — that read
        # alone must NOT make the knob look simulator-priced
        project = self._project(tmp_path)
        findings = list(self._rule().check(project))
        assert [f.rule for f in findings] == ["parity.one-clock"]

    def test_skips_silently_when_clocks_not_in_view(self, tmp_path):
        _write(tmp_path, "fake/cfg.py", self.CFG)
        project, _ = load_project([tmp_path], root=tmp_path)
        assert list(self._rule().check(project)) == []


# ---------------------------------------------------------------------------
# the committed tree is the negative fixture for everything at once
# ---------------------------------------------------------------------------

class TestRepoIsClean:
    def test_src_has_zero_unsuppressed_findings(self):
        rep = analyze([REPO / "src"], root=REPO)
        assert rep.active == [], "\n".join(f.render() for f in rep.active)

    def test_committed_baseline_is_empty(self):
        bl = Baseline.load(REPO / ".viblint-baseline.json")
        assert bl.findings == []
        assert bl.suppression_budget == 0
