"""Conservation/parity test battery for dispatch-time work stealing.

The :class:`~repro.core.steal.TokenRescheduler` reweights a replicated
placement's per-copy traffic shares between recalibrations. Everything it
may touch is pinned here:

* stolen share tables stay valid (per-expert sums exactly 1, nonnegative,
  phantom slots at 0) and their copy-CDF stays a valid CDF (monotone,
  in [0, 1], trailing 1.0);
* token conservation — realized per-rank loads under any stolen shares
  total exactly the drawn loads, and the ragged drop column stays 0;
* determinism — same tally stream, same shares, bit for bit;
* degeneration — r_max == 1 and balanced load never steal;
* engine/simulator integration — model outputs are bit-identical steal-on
  vs steal-off (replicas hold identical weights), steal updates never
  recompile the step functions, and both virtual clocks charge the share
  broadcast.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (DriftConfig, PerfModel, StealConfig,
                        TokenRescheduler, ViBEConfig, ViBEController,
                        vibe_r_placement)
from repro.serving import realized_rank_loads


def affine_perf(slopes, base=5e-4):
    return [PerfModel(knots=np.array([0.0, 1e6]),
                      lat=np.array([base, base + s * 1e6]), device_id=g)
            for g, s in enumerate(slopes)]


def zipf_w(rng, L, E, tokens=100_000.0, alpha=1.3):
    z = 1.0 / np.arange(1, E + 1) ** alpha
    return np.stack([rng.permutation(z / z.sum()) for _ in range(L)]) * tokens


def make_rescheduler(seed=0, L=3, E=16, G=4, slots_per_rank=6,
                     headroom=0.0, max_shift=0.25, smoothing=1.0):
    rng = np.random.default_rng(seed)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w0 = zipf_w(rng, L, E)
    rp = vibe_r_placement(w0, perf, slots_per_rank=slots_per_rank)
    rs = TokenRescheduler(StealConfig(headroom=headroom, max_shift=max_shift,
                                      smoothing=smoothing), perf)
    rs.reset(rp)
    return rng, perf, w0, rp, rs


# ---------------------------------------------------------------------------
# config validation
# ---------------------------------------------------------------------------

class TestStealConfig:
    def test_defaults_valid(self):
        StealConfig()

    @pytest.mark.parametrize("kw", [dict(headroom=-0.1), dict(max_shift=0.0),
                                    dict(max_shift=1.5), dict(interval=0),
                                    dict(smoothing=0.0), dict(smoothing=1.1)])
    def test_bad_knobs_rejected(self, kw):
        with pytest.raises(ValueError):
            StealConfig(**kw)

    def test_vibe_config_requires_replication(self):
        with pytest.raises(ValueError, match="supports_replication"):
            ViBEConfig(policy="vibe", steal=StealConfig())
        ViBEConfig(policy="vibe_r", steal=StealConfig())        # fine
        ViBEConfig(policy="harmoeny", steal=StealConfig())      # fine


# ---------------------------------------------------------------------------
# share-table validity under arbitrary steals (headline properties)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), steps=st.integers(1, 6),
       max_shift=st.floats(0.05, 1.0), headroom=st.floats(0.0, 0.3))
def test_stolen_shares_remain_valid_cdfs(seed, steps, max_shift, headroom):
    """After any number of steals the share table still sums to exactly 1
    per expert, stays nonnegative, keeps phantoms at 0, and its copy-CDF
    is monotone in [0, 1] with trailing 1.0 entries."""
    rng, _, _, rp, rs = make_rescheduler(seed=seed, headroom=headroom,
                                         max_shift=max_shift)
    L, E = rp.n_layers, rp.n_experts
    for _ in range(steps):
        rs.observe(rng.poisson(rng.dirichlet(np.full(E, 0.3), size=L)
                               * 50_000).astype(float))
    dp = rs.placement          # ReplicatedPlacement.__post_init__ validates
    assert dp.share.min() >= -1e-12
    sums = np.zeros((L, E + 1))
    np.add.at(sums, (np.arange(L)[:, None],
                     np.minimum(dp.slot_expert, E)), dp.share)
    np.testing.assert_allclose(sums[:, :E], 1.0, atol=1e-9)
    assert np.abs(dp.share[dp.slot_expert == E]).max(initial=0.0) <= 1e-12
    cdf = dp.copy_cdf()
    assert (np.diff(cdf, axis=-1) >= -1e-6).all()
    assert cdf.min() >= -1e-6 and cdf.max() <= 1.0 + 1e-6
    np.testing.assert_allclose(cdf[..., -1], 1.0, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), steps=st.integers(1, 6))
def test_token_conservation_under_any_steal(seed, steps):
    """Realized per-rank loads under stolen shares total exactly the drawn
    per-expert loads — stealing moves tokens between copies, never creates
    or drops them."""
    rng, _, _, rp, rs = make_rescheduler(seed=seed)
    L, E = rp.n_layers, rp.n_experts
    for _ in range(steps):
        rs.observe(rng.poisson(rng.dirichlet(np.full(E, 0.3), size=L)
                               * 50_000).astype(float))
    loads = np.round(rng.random((L, E)) * 5_000)
    got = realized_rank_loads(rs.placement, loads)
    base = realized_rank_loads(rp, loads)
    np.testing.assert_allclose(got.sum(axis=1), loads.sum(axis=1))
    np.testing.assert_allclose(got.sum(axis=1), base.sum(axis=1))
    np.testing.assert_allclose(got, np.round(got))   # whole tokens


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_steal_deterministic(seed):
    """Two reschedulers fed the identical tally stream produce bit-identical
    share tables and counters (no RNG anywhere in the steal path)."""
    rng = np.random.default_rng(seed)
    _, _, _, _, rs_a = make_rescheduler(seed=seed)
    _, _, _, _, rs_b = make_rescheduler(seed=seed)
    L, E = rs_a.placement.n_layers, rs_a.placement.n_experts
    stream = [rng.poisson(rng.dirichlet(np.full(E, 0.3), size=L)
                          * 50_000).astype(float) for _ in range(4)]
    for w in stream:
        changed_a = rs_a.observe(w)
        changed_b = rs_b.observe(w.copy())
        assert changed_a == changed_b
    np.testing.assert_array_equal(rs_a.placement.share, rs_b.placement.share)
    assert rs_a.version == rs_b.version and rs_a.steals == rs_b.steals
    assert rs_a.share_moved == rs_b.share_moved


# ---------------------------------------------------------------------------
# degenerate cases: must be exact no-ops
# ---------------------------------------------------------------------------

def test_r_max_one_never_steals():
    """A budget with no spare slots gives every expert one copy — removal
    always cancels, so shares never change."""
    rng = np.random.default_rng(3)
    perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
    w0 = zipf_w(rng, 2, 16)
    rp = vibe_r_placement(w0, perf, slots_per_rank=4)     # 16 slots = E
    assert int(rp.n_copies().max()) == 1
    rs = TokenRescheduler(StealConfig(headroom=0.0, smoothing=1.0), perf)
    rs.reset(rp)
    share0 = rs.placement.share.copy()
    for _ in range(5):
        assert not rs.observe(rng.poisson(w0 / 10))
    np.testing.assert_array_equal(rs.placement.share, share0)
    assert rs.steals == 0 and rs.share_moved == 0.0


def test_balanced_load_never_steals():
    """When the placement's predicted latencies are already level (the load
    it was solved for, uniform hardware), the headroom trigger never fires."""
    perf = affine_perf([2e-8] * 4)
    w0 = np.full((2, 16), 1000.0)
    rp = vibe_r_placement(w0, perf, slots_per_rank=6)
    rs = TokenRescheduler(StealConfig(headroom=0.05, smoothing=1.0), perf)
    rs.reset(rp)
    for _ in range(5):
        assert not rs.observe(w0)
    assert rs.steals == 0 and rs.version == 1


def test_skewed_load_on_slow_rank_does_steal():
    """Tripwire for the two no-op tests above: the same machinery must fire
    when load concentrates on the slowest rank's residents."""
    rng, perf, w0, rp, rs = make_rescheduler(seed=5, headroom=0.0)
    slow_residents = np.unique(rp.slot_expert[0, -rp.slots_per_rank:])
    slow_residents = slow_residents[slow_residents < rp.n_experts]
    w = np.full((rp.n_layers, rp.n_experts), 10.0)
    w[:, slow_residents] = 50_000.0
    assert rs.observe(w)
    assert rs.steals == 1 and rs.share_moved > 0.0
    # and the steal must not worsen the predicted straggler latency
    before = TokenRescheduler(rs.cfg, rs.perf_models)
    before.reset(rp)
    np.testing.assert_array_less(
        rs.predicted_latency(w).max(axis=1),
        before.predicted_latency(w).max(axis=1) + 1e-15)


def test_steal_moves_share_toward_faster_ranks():
    """Shares leave the hot rank's copies and land on sibling copies in
    proportion to receiving-rank speed (faster rank absorbs more)."""
    rng, perf, w0, rp, rs = make_rescheduler(seed=7, headroom=0.0,
                                             max_shift=0.5)
    w = rng.poisson(w0).astype(float)
    lat = rs.predicted_latency(w)
    hot = np.argmax(lat, axis=1)
    changed = rs.observe(w)
    if not changed:
        pytest.skip("fixture did not trigger on this seed")
    dp = rs.placement
    rank_of = np.arange(rp.n_slots) // rp.slots_per_rank
    for layer in range(rp.n_layers):
        on_hot = rank_of == hot[layer]
        d = dp.share[layer] - rp.share[layer]
        assert d[on_hot].sum() <= 1e-12          # hot rank only loses
        assert d.sum() == pytest.approx(0.0, abs=1e-9)


# ---------------------------------------------------------------------------
# measured-latency telemetry (thermal ramp between refits)
# ---------------------------------------------------------------------------

class TestLatencyTelemetry:
    """Measured per-rank latencies blended into the steal trigger: a rank
    running hot shows up as bias > 1 even when the (stale) f_g models say
    the fleet is uniform, so stealing reacts between perf refits."""

    def _fixture(self):
        # stale models: uniform fleet. slots_per_rank=8 doubles every
        # expert, so share can always leave a hot rank
        perf = affine_perf([2e-8] * 4)
        w0 = np.full((2, 16), 1000.0)
        rp = vibe_r_placement(w0, perf, slots_per_rank=8)
        rs = TokenRescheduler(StealConfig(headroom=0.05, smoothing=1.0),
                              perf)
        rs.reset(rp)
        return perf, w0, rp, rs

    def test_thermal_ramp_bias_triggers_steal(self):
        perf, w0, rp, rs = self._fixture()
        # control: balanced load + models in agreement → no trigger
        assert not rs.observe(w0)
        # thermal ramp the models know nothing about: rank 3 measures
        # 2.5x its prediction
        loads = rp.rank_loads(w0)[0]
        meas = np.array([float(m(l)) for m, l in zip(perf, loads)])
        meas[3] *= 2.5
        rs.observe_latency(loads, meas)
        assert rs._lat_bias is not None
        assert rs._lat_bias[3] == pytest.approx(2.5, rel=1e-6)
        np.testing.assert_allclose(rs._lat_bias[:3], 1.0, rtol=1e-6)
        # the same balanced load now looks like a straggler → steal fires
        # and share leaves the hot rank
        assert rs.observe(w0)
        assert rs.steals == 1 and rs.share_moved > 0.0
        rank_of = np.arange(rp.n_slots) // rp.slots_per_rank
        d = rs.placement.share - rp.share
        assert d[:, rank_of == 3].sum() < 0.0
        # without telemetry the identical sequence never fires (control)
        _, _, _, rs2 = self._fixture()
        assert not rs2.observe(w0) and not rs2.observe(w0)

    def test_ema_smoothing_and_reset(self):
        perf, w0, rp, _ = self._fixture()
        rs = TokenRescheduler(StealConfig(headroom=0.05, smoothing=0.5),
                              perf)
        rs.reset(rp)
        loads = rp.rank_loads(w0)[0]
        meas = np.array([float(m(l)) for m, l in zip(perf, loads)])
        rs.observe_latency(loads, meas * 2.0)
        rs.observe_latency(loads, meas)            # ratio 1 EMAs back down
        np.testing.assert_allclose(rs._lat_bias, 1.5, rtol=1e-6)
        # reset clears the bias — the recalibration's refit absorbed the
        # same drift; keeping it would double-count
        rs.reset(rp)
        assert rs._lat_bias is None

    def test_shape_mismatch_raises(self):
        perf, w0, rp, rs = self._fixture()
        with pytest.raises(ValueError, match="telemetry shapes"):
            rs.observe_latency(np.ones(3), np.ones(3))
        with pytest.raises(ValueError, match="telemetry shapes"):
            rs.observe_latency(np.ones(4), np.ones((2, 4)))

    def test_controller_feeds_rescheduler_without_perf_drift(self):
        """observe_latency retunes the steal trigger even when perf-drift
        refits are disabled — stealing covers the gap between refits."""
        L, E, G = 2, 16, 4
        perf = affine_perf([2e-8] * G)
        ctl = ViBEController(
            L, E, G, perf,
            ViBEConfig(policy="vibe_r", adaptive=False,
                       steal=StealConfig(headroom=0.05, smoothing=1.0),
                       drift=DriftConfig(window=8, interval=4, cooldown=4)),
            initial_w=np.full((L, E), 1000.0))
        loads = ctl.placement.rank_loads(np.full((L, E), 1000.0))[0]
        meas = np.array([float(m(l)) for m, l in zip(perf, loads)])
        meas[1] *= 3.0
        assert ctl.observe_latency(loads, meas) is None   # no refit path
        assert ctl.rescheduler._lat_bias is not None
        assert ctl.rescheduler._lat_bias[1] > 2.0


# ---------------------------------------------------------------------------
# controller lifecycle
# ---------------------------------------------------------------------------

class TestControllerIntegration:
    def _controller(self, adaptive=False, steal=True, seed=11):
        rng = np.random.default_rng(seed)
        perf = affine_perf([1e-8, 2e-8, 4e-8, 8e-8])
        w0 = zipf_w(rng, 3, 16)
        ctl = ViBEController(
            3, 16, 4, perf,
            ViBEConfig(policy="vibe_r", adaptive=adaptive,
                       drift=DriftConfig(window=8, interval=4, cooldown=4),
                       slot_budget=6,
                       steal=(StealConfig(headroom=0.0, smoothing=1.0)
                              if steal else None)),
            initial_w=w0)
        return rng, w0, ctl

    def test_dispatch_placement_tracks_steals(self):
        rng, w0, ctl = self._controller()
        assert ctl.dispatch_placement is ctl.rescheduler.placement
        base = ctl.placement
        for _ in range(6):
            ctl.observe(rng.poisson(np.roll(w0, 7, axis=1) / 5), tokens=1e4)
        assert ctl.rescheduler.steals > 0
        dp = ctl.dispatch_placement
        assert dp is not base
        np.testing.assert_array_equal(dp.slot_expert, base.slot_expert)
        assert np.abs(dp.share - base.share).max() > 0.0
        # the base plan itself is never mutated by steals
        assert ctl.placement is base

    def test_steal_runs_for_static_controllers(self):
        """adaptive=False disables recalibration, NOT stealing — the
        stale-profile regime is exactly what stealing exists for."""
        rng, w0, ctl = self._controller(adaptive=False)
        for _ in range(6):
            assert ctl.observe(rng.poisson(np.roll(w0, 7, axis=1) / 5),
                               tokens=1e4) is None
        assert ctl.rescheduler.steals > 0
        assert not ctl.updates

    def test_recalibration_resets_responsive_shares(self):
        rng, w0, ctl = self._controller(adaptive=True)
        for _ in range(10):                    # establish the drift reference
            ctl.observe(rng.poisson(w0 / 5), tokens=1e4)
        upd = None
        for _ in range(40):
            upd = upd or ctl.observe(rng.poisson(np.roll(w0, 7, axis=1) / 5),
                                     tokens=1e4)
        assert upd is not None, "no recalibration fired"
        # after a recalibration the responsive placement restarts at the
        # new plan (maybe already re-stolen since — same slot table though)
        np.testing.assert_array_equal(ctl.dispatch_placement.slot_expert,
                                      ctl.placement.slot_expert)
        assert ctl.rescheduler.version > ctl.rescheduler.steals

    def test_no_rescheduler_without_steal_config(self):
        _, _, ctl = self._controller(steal=False)
        assert ctl.rescheduler is None
        assert ctl.dispatch_placement is ctl.placement


# ---------------------------------------------------------------------------
# engine integration: outputs untouched, tables refreshed, no recompiles
# ---------------------------------------------------------------------------

class TestEngineSteal:
    def _engine(self, steal=True, weighted=True, headroom=0.0):
        from repro.configs import get_smoke
        from repro.core import make_cluster
        from repro.models import moe_perm_shape
        from repro.serving import Engine, EngineConfig

        cfg = get_smoke("qwen3-moe-235b-a22b")
        n_moe, E = moe_perm_shape(cfg, None, "train")
        cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                               d_ff=cfg.moe_d_ff, experts_per_rank=E // 4)
        # deliberately STALE skewed profile: the plan is solved for loads
        # the model will not produce, so stealing has real work to do
        rng = np.random.default_rng(9)
        stale = rng.dirichlet(np.full(E, 0.15), size=n_moe) * 8192
        ctl = ViBEController(
            n_moe, E, 4, cluster.fit_models(),
            ViBEConfig(policy="vibe_r", adaptive=False,
                       drift=DriftConfig(window=8, interval=4, cooldown=4),
                       steal=(StealConfig(headroom=headroom, smoothing=1.0)
                              if steal else None)),
            initial_w=stale)
        return Engine(cfg, EngineConfig(max_batch=2, max_seq=48, seed=0,
                                        weighted_routing=weighted),
                      controller=ctl, cluster=cluster)

    def _force_steal(self, eng):
        """Feed the rescheduler a tally stream guaranteed to trigger and
        push the resulting shares into the dispatch tables."""
        rs = eng.controller.rescheduler
        rng = np.random.default_rng(4)
        E = eng.controller.E
        w = rng.dirichlet(np.full(E, 0.2), size=eng.n_moe) * 4096
        for _ in range(5):
            rs.observe(w)
        assert rs.steals > 0, "fixture failed to trigger a steal"
        assert eng._steal_dirty()
        eng._apply_share()
        return rs

    def test_rejects_steal_with_uniform_routing(self):
        with pytest.raises(ValueError, match="weighted_routing"):
            self._engine(steal=True, weighted=False)

    def test_steal_refreshes_dispatch_tables_in_place(self):
        eng = self._engine()
        shapes0 = tuple(t.shape for t in eng.moe_tables)
        rs = self._force_steal(eng)
        assert eng.stats.steal_updates == 1
        assert tuple(t.shape for t in eng.moe_tables) == shapes0
        cdf = np.asarray(eng.moe_tables[2]).reshape(eng.n_moe,
                                                    eng.cfg.n_experts, -1)
        want = rs.placement.copy_cdf(r_max=cdf.shape[-1])
        np.testing.assert_allclose(cdf, want, atol=1e-6)
        # base-plan tables would NOT match any more
        base = eng.controller.placement.copy_cdf(r_max=cdf.shape[-1])
        assert np.abs(cdf - base).max() > 1e-4

    def test_steal_preserves_model_outputs(self):
        """Replica copies hold identical weights, so stolen shares change
        which copy serves a token but never the logits: steal-on tables and
        steal-off (plan) tables produce equal prefill outputs."""
        import jax.numpy as jnp
        eng_on = self._engine(steal=True)
        eng_off = self._engine(steal=False)
        self._force_steal(eng_on)
        prompt = jnp.arange(12, dtype=jnp.int32)[None, :] % eng_on.cfg.vocab
        lg_on, _, _ = eng_on._prefill(eng_on.params, {"tokens": prompt},
                                      eng_on.moe_tables)
        lg_off, _, _ = eng_off._prefill(eng_off.params, {"tokens": prompt},
                                        eng_off.moe_tables)
        np.testing.assert_allclose(np.asarray(lg_on), np.asarray(lg_off),
                                   atol=1e-5, rtol=1e-5)
        # and greedy token choices are bit-identical
        np.testing.assert_array_equal(np.asarray(lg_on).argmax(-1),
                                      np.asarray(lg_off).argmax(-1))

    def test_share_broadcast_charged_to_virtual_clock(self):
        eng = self._engine()
        vt0 = eng.stats.virtual_time
        rs = self._force_steal(eng)
        assert eng.stats.virtual_time - vt0 == pytest.approx(
            rs.share_table_bytes / eng.cluster.ici_bw)

    def test_no_recompile_across_steal_updates(self):
        """Steal updates swap table *contents* (same shapes/dtypes), so the
        compiled step functions' caches stay exactly as large as a steal-off
        run's — zero extra compilations."""
        from repro.serving import WORKLOADS, sample_requests

        def run(eng):
            reqs = sample_requests(WORKLOADS["sharegpt"], 3, qps=100.0,
                                   seed=1)
            reqs = [type(r)(r.req_id, r.arrival, 8, 6) for r in reqs]
            eng.submit(reqs)
            records = eng.run(max_steps=200)
            assert sum(np.isfinite(r.finished_at) for r in records) == 3
            return {name: fn._cache_size()
                    for name, fn in (("prefill", eng._prefill),
                                     ("decode", eng._decode))
                    if hasattr(fn, "_cache_size")}

        eng_off = self._engine(steal=False)
        sizes_off = run(eng_off)
        eng_on = self._engine(steal=True)
        # guarantee at least one mid-run steal update regardless of how the
        # randomly-initialized router happens to route
        self._force_steal(eng_on)
        sizes_on = run(eng_on)
        assert eng_on.stats.steal_updates >= 1
        assert sizes_on == sizes_off
        if not sizes_on:                      # jit cache introspection gone?
            pytest.skip("jax jit _cache_size() unavailable")


# ---------------------------------------------------------------------------
# simulator integration: stalls priced, runs deterministic
# ---------------------------------------------------------------------------

class TestSimulatorSteal:
    def _sim(self, steal=True):
        from repro.configs import get
        from repro.core import make_cluster
        from repro.serving import (EPSimulator, SimConfig, WORKLOADS,
                                   routing_profile)

        model = get("deepseek-v3-671b")
        wl = WORKLOADS["sonnet"]
        cluster = make_cluster(8, "mi325x", d_model=model.d_model,
                               d_ff=model.moe_d_ff,
                               experts_per_rank=model.n_experts // 8)
        L, E = model._n_moe_layers(), model.n_experts
        W = routing_profile(wl, L, E) * 16384 * model.top_k
        ctl = ViBEController(
            L, E, 8, cluster.fit_models(),
            ViBEConfig(policy="vibe_r", adaptive=False,
                       steal=(StealConfig(headroom=0.0, smoothing=1.0)
                              if steal else None)),
            initial_w=W)
        sim = EPSimulator(model, cluster, wl,
                          SimConfig(ep_degree=8, seed=3,
                                    max_prefill_tokens=16384),
                          controller=ctl)
        return sim, ctl

    def _run(self, sim):
        from repro.serving import (WORKLOADS, routing_profile,
                                   sample_requests)
        wl = WORKLOADS["sonnet"]
        reqs = sample_requests(wl, 40, qps=20.0, seed=4)
        # serve a DIFFERENT routing mix than profiled → stale-plan regime
        drift = routing_profile(WORKLOADS["sharegpt"],
                                sim.controller.L, sim.controller.E)
        return sim.run(reqs, phase="prefill", drift_profile=drift,
                       drift_at=0.0)

    def test_simulator_prices_steal_updates(self):
        sim, ctl = self._sim(steal=True)
        self._run(sim)
        assert ctl.rescheduler.steals > 0
        assert sim.steal_updates > 0
        assert not ctl.updates              # static controller: pure steal

    def test_simulator_steal_run_deterministic(self):
        def once():
            sim, ctl = self._sim(steal=True)
            recs = self._run(sim)
            return ([(r.req_id, r.first_token_at, r.finished_at)
                     for r in recs],
                    ctl.rescheduler.steals, sim.steal_updates,
                    ctl.rescheduler.placement.share.copy())
        ra, sa, ua, sha = once()
        rb, sb, ub, shb = once()
        assert ra == rb and sa == sb and ua == ub
        np.testing.assert_array_equal(sha, shb)
