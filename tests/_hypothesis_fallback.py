"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The test suite's property tests use a small slice of the hypothesis API:
``@settings(max_examples=N, deadline=None)`` stacked on ``@given(**kwargs)``
with ``st.integers`` / ``st.sampled_from`` style strategies. Some dev
containers cannot install hypothesis (no network); rather than losing the
property tests there, ``conftest.py`` registers this module under the
``hypothesis`` / ``hypothesis.strategies`` names when the real import
fails. CI installs real hypothesis and never sees this file.

The fallback runs each property ``max_examples`` times with values drawn
from a per-test seeded numpy generator — deterministic across runs, so a
failure is reproducible, but with no shrinking or example database.
"""

from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A draw(rng) callable; covers the strategy surface the suite uses."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries: int = 1000):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return _Strategy(draw)


class _Strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elem.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*elems):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


strategies = _Strategies()


class HealthCheck:
    """Placeholder so ``suppress_health_check=[...]`` settings parse."""
    too_slow = data_too_large = filter_too_much = all = None


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_kw):
    """Decorator recording ``max_examples``; other knobs are no-ops here."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    """Run the test ``max_examples`` times with deterministic draws.

    Keyword-only, matching the suite's usage; works on plain functions and
    methods (positional args — e.g. ``self`` — pass through untouched).
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = {k: s.example(rng)
                         for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"property falsified on example {i + 1}/{n}: "
                        f"{drawn!r}") from e

        # hide the drawn parameters from pytest's fixture resolution (real
        # hypothesis does the same); keep `self` so method collection works
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.name not in strategy_kwargs]
        wrapper.__signature__ = inspect.Signature(params)
        del wrapper.__wrapped__
        return wrapper
    return deco
