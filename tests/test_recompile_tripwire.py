"""Runtime recompile tripwire: the engine's no-recompile contract, counted.

The static ``trace`` rule proves no *code pattern* can trigger a
recompile; this test proves the *running engine* doesn't: after a warmup
that compiles each step function once per shape signature, a full
production episode — chunked prefill, decode, a forced work-steal share
refresh, and a forced recalibration — must add **zero** entries to any
jit cache. ``PjitFunction._cache_size()`` counts compiled signatures
directly, so a single silent recompile (a shape leak, a weak-type flip, a
traced-value branch that specializes) fails the assert with the exact
cache that grew.
"""

import dataclasses

import numpy as np

from repro.configs import get_smoke
from repro.core import (DriftConfig, StealConfig, ViBEConfig,
                        ViBEController, make_cluster)
from repro.models import moe_perm_shape
from repro.serving import (Engine, EngineConfig, SchedulerConfig,
                           WORKLOADS, sample_requests)

ARCH = "qwen3-moe-235b-a22b"


def _engine():
    """Adaptive controller + steal + chunked prefill: every moving part
    that refreshes dispatch state between compiles is live at once."""
    cfg = get_smoke(ARCH)
    n_moe, E = moe_perm_shape(cfg, None, "train")
    cluster = make_cluster(4, "mi325x", d_model=cfg.d_model,
                           d_ff=cfg.moe_d_ff, experts_per_rank=E // 4)
    rng = np.random.default_rng(9)
    stale = rng.dirichlet(np.full(E, 0.15), size=n_moe) * 8192
    ctl = ViBEController(
        n_moe, E, 4, cluster.fit_models(),
        ViBEConfig(policy="vibe_r", adaptive=True,
                   drift=DriftConfig(window=8, interval=4, cooldown=4),
                   steal=StealConfig(headroom=0.0, smoothing=1.0)),
        initial_w=stale)
    return Engine(
        cfg,
        EngineConfig(max_batch=2, max_seq=48, seed=0, weighted_routing=True,
                     scheduler=SchedulerConfig(name="slo_edf",
                                               prefill_chunk=8)),
        controller=ctl, cluster=cluster)


def _cache_sizes(eng):
    out = {}
    for name in ("_prefill", "_decode", "_prefill_chunk"):
        fn = getattr(eng, name)
        if fn is not None:
            out[name] = fn._cache_size()
    return out


def _requests(n, seed, start_id=0):
    reqs = sample_requests(WORKLOADS["sharegpt"], n, qps=100.0, seed=seed)
    return [dataclasses.replace(r, req_id=start_id + i, prompt_len=20,
                                output_len=6)
            for i, r in enumerate(reqs)]


def _force_steal(eng):
    rs = eng.controller.rescheduler
    rng = np.random.default_rng(4)
    E = eng.controller.E
    w = rng.dirichlet(np.full(E, 0.2), size=eng.n_moe) * 4096
    for _ in range(5):
        rs.observe(w)
    assert rs.steals > 0, "fixture failed to trigger a steal"
    assert eng._steal_dirty()
    eng._apply_share()


def _force_recalibration(eng):
    ctl = eng.controller
    rng = np.random.default_rng(7)
    w0 = rng.dirichlet(np.full(ctl.E, 0.15), size=eng.n_moe) * 8192
    upd = None
    for k in range(64):
        upd = upd or ctl.observe(
            rng.poisson(np.roll(w0, 3 + k // 16, axis=1) / 5), tokens=1e4)
        if upd is not None:
            break
    assert upd is not None, "fixture failed to trigger a recalibration"
    eng._apply_perm(eng._controller_perm())


class TestRecompileTripwire:
    def test_zero_compiles_after_warmup(self):
        eng = _engine()
        assert all(s == 0 for s in _cache_sizes(eng).values())

        # warmup episode: chunked prefill + decode compile once each
        eng.submit(_requests(4, seed=0))
        records = eng.run(max_steps=300)
        assert sum(np.isfinite(r.finished_at) for r in records) == 4
        assert eng.stats.chunk_steps >= 4 * 3   # 20 tokens / chunks of 8
        warm = _cache_sizes(eng)
        assert warm["_prefill_chunk"] >= 1
        assert warm["_decode"] >= 1

        # share refresh (work stealing) + recalibration (new placement) +
        # a second full episode: all dispatch-state churn, zero compiles
        _force_steal(eng)
        assert eng.stats.steal_updates >= 1
        _force_recalibration(eng)
        assert eng.stats.migrations >= 1
        eng.submit(_requests(4, seed=1, start_id=100))
        records = eng.run(max_steps=300)   # cumulative: both episodes
        assert sum(np.isfinite(r.finished_at) for r in records) == 8

        after = _cache_sizes(eng)
        grew = {k: (warm[k], after[k]) for k in warm if after[k] > warm[k]}
        assert not grew, (
            f"jit caches grew after warmup: {grew} — a recalibration, "
            "share refresh or chunked-prefill step recompiled")

    def test_cache_size_counter_is_live(self):
        """Guard the tripwire's own instrument: _cache_size must actually
        count compiles (a vacuous 0-forever counter would green-light
        every recompile)."""
        import jax
        import jax.numpy as jnp

        f = jax.jit(lambda x: x * 2)
        assert f._cache_size() == 0
        f(jnp.zeros(3))
        assert f._cache_size() == 1
        f(jnp.zeros(3))
        assert f._cache_size() == 1
        f(jnp.zeros(5))                 # new shape → one more compile
        assert f._cache_size() == 2
